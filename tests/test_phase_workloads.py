"""Decode-phase KV-cache op lists, MoE expert-parallel alltoall
compilation, and the prefill/decode flops-bytes crossover on the
analytic model (ISSUE 3 tentpole coverage)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import (lm_layer_ops, lm_workload_name, resolve_workload, workload_bytes, workload_flops)
from repro.hw.ici import CollectiveSpec
from repro.hw.presets import resolve_preset

DENSE = get_config("qwen3-32b")
MOE = get_config("qwen3-moe-30b-a3b")


# -- decode op lists -------------------------------------------------------

def test_decode_kv_bytes_grow_linearly_in_kv_len():
    """KV-cache read/append traffic is linear in kv_len: equal kv_len
    increments add equal byte increments (and flops stay attention-only
    linear too)."""
    sizes = [1024, 2048, 3072, 4096]
    totals = [workload_bytes(lm_layer_ops(DENSE, batch=4, phase="decode",
                                          kv_len=kv)) for kv in sizes]
    deltas = np.diff(totals)
    assert np.all(deltas > 0)
    assert np.allclose(deltas, deltas[0])
    # the per-step KV read is GQA-aware: kv heads only, both K and V
    ops = lm_layer_ops(DENSE, batch=4, phase="decode", kv_len=2048)
    kv_side = 4 * DENSE.n_kv_heads * 2048 * DENSE.hd * 2
    scores = next(o for o in ops if o.name == "scores")
    assert scores.in_bytes == 4 * DENSE.n_heads * DENSE.hd * 2 + kv_side
    assert scores.stream and next(o for o in ops if o.name == "pv").stream


def test_decode_gemv_shapes_under_tp_and_gqa():
    """Decode GEMMs are m=batch GEMVs; TP divides q heads and GQA kv
    heads; score/pv contract over kv_len."""
    for tp in (1, 2, 4):
        ops = lm_layer_ops(DENSE, batch=8, phase="decode", kv_len=4096,
                           tp_shards=tp)
        by = {o.name: o for o in ops}
        H = DENSE.n_heads // tp
        KV = DENSE.n_kv_heads // tp
        assert by["qkv"].m == 8                      # one token/sequence
        assert by["qkv"].n == (H + 2 * KV) * DENSE.hd
        assert by["scores"].m == 8 * H
        assert by["scores"].n == 4096 and by["scores"].k == DENSE.hd
        assert by["pv"].k == 4096 and by["pv"].n == DENSE.hd
        assert by["kv_append"].elems == 2 * 8 * KV * DENSE.hd
        assert ("attn_allreduce" in by) == (tp > 1)


def test_phase_validation_errors():
    with pytest.raises(ValueError):
        lm_layer_ops(DENSE, batch=1, phase="decode")          # no kv_len
    with pytest.raises(ValueError):
        lm_layer_ops(DENSE, batch=1, phase="prefill")         # no seq
    with pytest.raises(ValueError):
        lm_layer_ops(DENSE, seq=64, batch=1, kv_len=64)       # kv in prefill
    with pytest.raises(ValueError):
        lm_layer_ops(DENSE, seq=64, batch=1, phase="bogus")
    with pytest.raises(ValueError):
        lm_layer_ops(DENSE, seq=64, batch=1, ep_shards=4)     # dense EP


def test_decode_workload_names_resolve():
    name = lm_workload_name("qwen3-32b", phase="decode", kv_len=4096,
                            batch=8, tp=2)
    assert name == "lm/qwen3-32b/decode/kv4096b8tp2"
    ops = resolve_workload(name)()
    assert any(o.name == "kv_append" for o in ops)
    # prefill names keep their historical spelling
    assert lm_workload_name("qwen3-32b", seq=64, batch=1, tp=1) == \
        "lm/qwen3-32b/s64b1tp1"
    with pytest.raises(KeyError):
        resolve_workload("lm/qwen3-32b/decode/kv0b1tp1")      # kv < 1
    with pytest.raises(KeyError):
        resolve_workload("lm/qwen3-32b/s64b1tp1ep4")          # dense EP
    with pytest.raises(KeyError):
        resolve_workload("lm/qwen3-32b/decode/s64b1tp1")      # bad grammar


# -- alltoall compilation --------------------------------------------------

@pytest.mark.parametrize("ep", [1, 2, 8, 16])
def test_moe_ep_alltoall_compilation(ep):
    """EP>1 compiles exactly two alltoall collectives per MoE layer
    (dispatch + combine) onto the ICI engine, each a single-task layer
    with one signal barrier; their ring phase count follows the EP
    degree."""
    ops = lm_layer_ops(MOE, seq=128, batch=2, ep_shards=ep)
    cfg = resolve_preset("v5e")
    cw = compile_ops(ops, cfg, CompileOptions(n_tiles=2))
    coll = [t for t in cw.tasks if t.engine == "ici"]
    if ep == 1:
        assert coll == []
        return
    assert [t.payload.op for t in coll] == ["all-to-all", "all-to-all"]
    for t in coll:
        assert isinstance(t.payload, CollectiveSpec)
        assert t.payload.group_size == ep
        assert t.payload.phases() == ep - 1          # ring schedule
        assert len(t.signals) == 1                   # own barrier...
        assert len(t.waits) == 1                     # ...chained to prev
        assert t.payload.payload_bytes > 0
    # dispatch precedes the expert GEMMs, combine follows them
    names = [t.name for t in cw.tasks]
    assert names.index("moe_dispatch") < names.index("experts_up@t0")
    assert names.index("moe_combine") > names.index("experts_down@t0")


def test_moe_ep_with_tp_mixes_collectives():
    """EP + TP: attention keeps its Megatron allreduce, the MoE combine
    becomes the EP alltoall (no mlp_allreduce)."""
    ops = lm_layer_ops(MOE, seq=128, batch=2, tp_shards=2, ep_shards=8)
    kinds = [o.kind for o in ops if o.kind in ("allreduce", "alltoall")]
    assert kinds == ["alltoall", "allreduce", "alltoall"] or \
        kinds == ["allreduce", "alltoall", "alltoall"]
    names = [o.name for o in ops]
    assert "mlp_allreduce" not in names
    assert "attn_allreduce" in names
    # ep==1 keeps the historical expert-TP shape: combine is allreduce
    ops1 = lm_layer_ops(MOE, seq=128, batch=2, tp_shards=2, ep_shards=1)
    assert "mlp_allreduce" in [o.name for o in ops1]
    assert not any(o.kind == "alltoall" for o in ops1)


def test_moe_ep_shards_expert_weights():
    """Higher EP degree -> fewer local experts -> less weight traffic,
    while the alltoall payload tracks the local token load."""
    w = {}
    for ep in (1, 8, 16):
        ops = lm_layer_ops(MOE, seq=256, batch=1, ep_shards=ep)
        w[ep] = sum(o.w_bytes for o in ops if o.name.startswith("experts"))
    assert w[8] < w[1] and w[16] < w[8]
    assert w[1] / w[16] == pytest.approx(16, rel=0.01)


# -- prefill/decode crossover on the analytic model ------------------------

def test_decode_more_hbm_bound_than_matching_prefill():
    """Compiled intensity (flops/byte): a decode step at kv_len=L sits
    far below the matching prefill pass at seq=L for every batch/TP —
    the campaign-record acceptance property."""
    cfg = resolve_preset("v5e")
    for batch in (1, 8):
        for tp in (1, 4):
            pre = compile_ops(
                lm_layer_ops(DENSE, seq=1024, batch=batch, tp_shards=tp),
                cfg, CompileOptions(n_tiles=2))
            dec = compile_ops(
                lm_layer_ops(DENSE, batch=batch, phase="decode",
                             kv_len=1024, tp_shards=tp),
                cfg, CompileOptions(n_tiles=2))
            assert pre.hbm_bytes > 0 and dec.hbm_bytes > 0
            assert (dec.total_flops / dec.hbm_bytes) < \
                (pre.total_flops / pre.hbm_bytes)


def test_analytic_crossover_hbm_sensitivity():
    """Prefill/decode flops-bytes crossover sanity on the analytic
    model: the two phases land on opposite sides of the chip's ridge
    point, and halving HBM bandwidth hurts the decode makespan more
    than the matching prefill makespan."""
    from repro.core.vectorized import from_tasks, params_of, schedule_many

    cfg = resolve_preset("v5e")
    ridge = cfg.peak_tflops * 1e12 / (cfg.hbm_bytes_per_ns * 1e9)
    pre = compile_ops(lm_layer_ops(DENSE, seq=1024, batch=1), cfg,
                      CompileOptions(n_tiles=2))
    dec = compile_ops(lm_layer_ops(DENSE, batch=1, phase="decode",
                                   kv_len=1024), cfg,
                      CompileOptions(n_tiles=2))
    # intensity crossover: decode below the ridge, prefill above it
    assert dec.total_flops / dec.hbm_bytes < ridge
    assert pre.total_flops / pre.hbm_bytes > ridge

    lo = cfg.replace(hbm_gbps=cfg.hbm_gbps / 2)
    pm = np.stack([params_of(lo), params_of(cfg)])

    def bw_speedup(cw):
        t = schedule_many(from_tasks(cw.tasks), pm)
        return float(t[0] / t[1])

    s_dec, s_pre = bw_speedup(dec), bw_speedup(pre)
    assert s_dec > 1.5          # memory-bound: BW cuts the step time
    # the un-fused score matrix keeps prefill partially BW-sensitive
    # in this op-list model, but decode must clearly dominate
    assert s_pre < 1.4
    assert s_dec > s_pre


def test_model_ops_restream_weights_per_layer():
    """Full-model composition re-streams every layer's weights from HBM:
    weight bytes scale linearly with the layer count, and each layer's
    ops carry its own L<i>. prefix."""
    from repro.graph.workloads import lm_model_ops

    def w_bytes(layers):
        ops = lm_model_ops(DENSE, layers=layers, seq=64, batch=2)
        return sum(o.w_bytes for o in ops if o.name != "lm_head")

    assert w_bytes(4) == pytest.approx(4 * w_bytes(1), rel=1e-12)
    ops = lm_model_ops(DENSE, layers=3, seq=64, batch=2)
    prefixes = {o.name.split(".", 1)[0] for o in ops if "." in o.name}
    assert prefixes == {"L0", "L1", "L2"}
    assert [o.name for o in ops[-2:]] == ["final_norm", "lm_head"]
    # the LM head is vocab-sharded under TP
    head1 = next(o for o in lm_model_ops(DENSE, layers=1, seq=64, batch=2)
                 if o.name == "lm_head")
    head4 = next(o for o in lm_model_ops(DENSE, layers=1, seq=64, batch=2,
                                         tp_shards=4)
                 if o.name == "lm_head")
    assert head4.n == head1.n // 4


def test_train_phase_dp_gradient_vs_inference_none():
    """DP semantics per phase: train appends ONE gradient all-reduce
    over the per-device weight-shard bytes (group=dp, backward modeled
    as dgrad+wgrad copies); prefill/decode DP adds no collective, only
    shards the global batch."""
    from repro.graph.workloads import lm_model_ops

    tr = lm_model_ops(DENSE, layers=2, seq=64, batch=8, phase="train",
                      dp_shards=4, tp_shards=2)
    gar = [o for o in tr if o.name == "grad_allreduce"]
    assert len(gar) == 1 and gar[0].group == 4
    fwd_w = sum(o.w_bytes for o in lm_model_ops(
        DENSE, layers=2, seq=64, batch=8, phase="train", dp_shards=1,
        tp_shards=2) if o.name.startswith("L") and
        ".dgrad." not in o.name and ".wgrad." not in o.name)
    head_w = next(o.w_bytes for o in tr if o.name == "lm_head")
    assert gar[0].in_bytes == pytest.approx(fwd_w + head_w, rel=1e-9)
    # dgrad re-runs the TP collectives, wgrad runs none and reads no
    # weights (it produces them)
    assert any(".dgrad.attn_allreduce" in o.name for o in tr)
    assert not any(".wgrad." in o.name and o.kind == "allreduce"
                   for o in tr)
    assert all(o.w_bytes == 0 for o in tr if ".wgrad." in o.name)
    # inference DP: same op kinds as DP=1, just a smaller local batch
    inf1 = lm_model_ops(DENSE, layers=2, seq=64, batch=8, dp_shards=1)
    inf4 = lm_model_ops(DENSE, layers=2, seq=64, batch=8, dp_shards=4)
    assert [o.name for o in inf1] == [o.name for o in inf4]
    assert not any(o.name == "grad_allreduce" for o in inf4)
    assert workload_flops(inf4) < workload_flops(inf1)


def test_pod_placement_sets_cross_pod_flags():
    """PodShape placement: TP innermost, EP middle, DP outermost; a
    collective crosses pods iff its group span exceeds pod_chips."""
    from repro.graph.workloads import lm_model_ops
    from repro.hw.pod import PodShape

    pod = PodShape(dp=4, tp=4, ep=1, pod_chips=8)
    assert pod.chips == 16 and pod.n_pods == 2
    assert not pod.crosses_pod("tp")     # span 4 <= 8
    assert pod.crosses_pod("dp")         # span 16 > 8
    ops = lm_model_ops(DENSE, layers=1, seq=64, batch=8, phase="train",
                       dp_shards=4, tp_shards=4, pod_chips=8)
    by_kind = {}
    for o in ops:
        if o.kind == "allreduce":
            by_kind.setdefault(o.name.split(".")[-1], o)
    assert not by_kind["attn_allreduce"].cross_pod      # TP in-pod
    assert by_kind["grad_allreduce"].cross_pod          # DP spans pods
    # TP=16 on the same 8-chip pods: the TP ring itself leaves the pod
    wide = lm_model_ops(DENSE, layers=1, seq=64, batch=8, tp_shards=16,
                        pod_chips=8)
    assert all(o.cross_pod for o in wide if o.kind == "allreduce")
    # EP sits between TP and DP
    ep_ops = lm_model_ops(MOE, layers=1, seq=64, batch=8, tp_shards=2,
                          ep_shards=8, pod_chips=8)
    assert all(o.cross_pod for o in ep_ops if o.kind == "alltoall")


def test_model_args_validation():
    from repro.graph.workloads import lm_model_ops

    with pytest.raises(ValueError):      # batch must divide over DP
        lm_model_ops(DENSE, layers=2, seq=64, batch=3, dp_shards=2)
    with pytest.raises(ValueError):      # layers >= 1
        lm_model_ops(DENSE, layers=0, seq=64, batch=2)
    with pytest.raises(ValueError):      # train needs seq, not kv_len
        lm_model_ops(DENSE, layers=2, batch=2, phase="train", kv_len=64)
    with pytest.raises(ValueError):      # bogus phase
        lm_model_ops(DENSE, layers=2, seq=64, batch=2, phase="serve")


def test_decode_flops_scale_with_batch_not_ctx():
    """Decode flops are O(batch) in the projections and O(batch*kv) in
    attention only — doubling kv_len must not double total flops the
    way doubling prefill seq does."""
    f_kv1 = workload_flops(lm_layer_ops(DENSE, batch=4, phase="decode",
                                        kv_len=1024))
    f_kv2 = workload_flops(lm_layer_ops(DENSE, batch=4, phase="decode",
                                        kv_len=2048))
    assert f_kv2 / f_kv1 < 1.5
    f_s1 = workload_flops(lm_layer_ops(DENSE, seq=1024, batch=4))
    f_s2 = workload_flops(lm_layer_ops(DENSE, seq=2048, batch=4))
    assert f_s2 / f_s1 > 1.9
