"""Differential harness for captured-HLO ingestion (graph/ingest.py).

Four contracts, per fixture under ``src/repro/configs/hlo/``:

* conservation — the ingested ``CompiledWorkload``'s total FLOPs and
  HBM bytes stay within 5% of ``hlo_parser.summarize``'s independent
  trip-aware totals of the same text;
* deviation — the analytic pre-screen latency of the ingested graph vs
  its hand-built ``lm/...`` twin lands in the per-fixture band
  documented in the fixture manifest (the ``hlo_crosscheck`` campaign's
  acceptance bar, asserted here through the real campaign path);
* engine agreement — the fast engine extrapolates ingested graphs from
  their ``@L<k>`` reduced twins with intervals matching a full event
  replay to noise (<= 1e-3 ns absolute, <= 1e-9 relative records);
* determinism — same HLO text, same byte-identical op table and
  structural hash (hypothesis property).
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import ingest
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.hlo_parser import summarize
from repro.graph.workloads import is_workload, resolve_workload
from repro.hw.presets import resolve_preset, to_dict

FIXTURES = ingest.fixture_names()
assert FIXTURES, "HLO fixtures missing — run tools/gen_hlo_fixtures.py"


# -- conservation ----------------------------------------------------------

@pytest.mark.parametrize("fixture", FIXTURES)
def test_compiled_totals_within_5pct_of_summarize(fixture):
    meta = ingest.fixture_meta(fixture)
    text = ingest.load_fixture(fixture)
    s = summarize(text, pod_size=int(meta.get("pod_size", 0)))
    cfg = resolve_preset("v5e")
    cw = compile_ops(resolve_workload(f"hlo/{fixture}")(), cfg,
                     CompileOptions(n_tiles=2))
    # Op.flops is 2mnk for matmuls and elems for eltwise ops, so the
    # compiled total tracks the parser's mxu + vector work combined
    assert cw.total_flops == pytest.approx(s.flops + s.vector_elems,
                                           rel=0.05)
    assert cw.hbm_bytes == pytest.approx(s.hbm_bytes, rel=0.05)


@pytest.mark.parametrize("fixture", FIXTURES)
def test_report_matches_parser(fixture):
    meta = ingest.fixture_meta(fixture)
    _, rep = ingest.ingest_fixture(fixture)
    text = ingest.load_fixture(fixture)
    s = summarize(text, pod_size=int(meta.get("pod_size", 0)))
    assert rep.mxu_flops == pytest.approx(s.flops, rel=0.05)
    assert rep.vector_elems == pytest.approx(s.vector_elems, rel=0.05)
    assert rep.n_layers == meta["layers"]
    assert rep.layer_ops > 0
    assert rep.dropped_collectives == 0


# -- layer blocks / reduced twins ------------------------------------------

@pytest.mark.parametrize("fixture", FIXTURES)
def test_layer_blocks_lead_and_reduce(fixture):
    ops, rep = ingest.ingest_fixture(fixture)
    # fastsim's _block_slices contract: L0 opens the list, blocks are
    # contiguous and ascending, non-layer ops form the tail
    labels = [op.name.split(".")[0] for op in ops]
    assert labels[0] == "L0"
    seen_tail = False
    last = -1
    for lab in labels:
        if lab.startswith("L") and lab[1:].isdigit():
            assert not seen_tail, "layer block after tail began"
            li = int(lab[1:])
            assert li in (last, last + 1)
            last = max(last, li)
        else:
            seen_tail = True
    assert last == rep.n_layers - 1

    red_ops, red = ingest.ingest_fixture(fixture, layers_keep=4)
    assert red.n_layers == 4
    # reduction keeps the non-layer head/tail intact
    full_tail = [o.name for o in ops if not o.name.startswith("L")]
    red_tail = [o.name for o in red_ops if not o.name.startswith("L")]
    assert red_tail == full_tail
    assert len(red_ops) < len(ops)


def test_bad_names_raise_keyerror():
    with pytest.raises(KeyError, match="hlo/"):
        resolve_workload("hlo/")
    with pytest.raises(KeyError, match="unknown HLO fixture"):
        resolve_workload("hlo/no_such_fixture")
    with pytest.raises(KeyError, match="out of range"):
        resolve_workload(f"hlo/{FIXTURES[0]}@L999")
    assert is_workload(f"hlo/{FIXTURES[0]}")
    assert is_workload(f"hlo/{FIXTURES[0]}@L4")


def test_twins_resolve():
    for fx in FIXTURES:
        assert is_workload(ingest.twin_name(fx))
        # reduced-twin rewrite targets the layer segment
        assert "/L4/" in ingest.twin_name(fx, layers=4)


def test_engine_routing():
    from repro.sweep.refine import _reduced_workloads, resolve_engine

    for fx in FIXTURES:
        name = f"hlo/{fx}"
        assert resolve_engine("auto", name) == "fast"
        assert resolve_engine("auto", name + "@L4") == "event"
        reduced = _reduced_workloads(name)
        assert reduced and all(r.startswith(name + "@L") for r in reduced)
        assert _reduced_workloads(name + "@L4") == []


# -- deviation band (the crosscheck campaign's acceptance bar) -------------

@pytest.mark.parametrize("fixture", FIXTURES)
def test_analytic_deviation_in_documented_band(fixture):
    """Run the builtin hlo_crosscheck campaign's pre-screen (refinement
    off — the band is an analytic-latency contract) and assert every
    cell of this fixture lands inside its manifest band."""
    res = _campaign()
    xck = res.summary["hlo_crosscheck"]
    assert fixture in xck, f"campaign never paired {fixture}"
    s = xck[fixture]
    assert s["band"] == ingest.fixture_meta(fixture)["band"]
    assert s["cells"] >= 2
    assert s["in_band"] == s["cells"], (
        f"{fixture}: analytic ratio range "
        f"[{s['analytic_ratio_min']:.3f}, {s['analytic_ratio_max']:.3f}] "
        f"escapes documented band {s['band']}")
    lo, hi = s["band"]
    assert lo <= s["analytic_ratio_min"] <= s["analytic_ratio_max"] <= hi


def test_crosscheck_records_carry_deviation():
    res = _campaign()
    hlo_recs = [r for r in res.records if r["workload"].startswith("hlo/")]
    assert hlo_recs
    for r in hlo_recs:
        dev = r["hlo_deviation"]
        assert r["hlo_twin"] == ingest.twin_name(
            ingest.parse_hlo_name(r["workload"])["fixture"])
        assert dev["in_band"]
        assert dev["analytic_ratio"] > 0
        assert dev["flops_ratio"] == pytest.approx(1.0, rel=0.2)
        assert dev["hbm_ratio"] > 1.0     # f32 capture + no-reuse bytes


_CAMPAIGN_CACHE = []


def _campaign():
    if not _CAMPAIGN_CACHE:
        from repro.sweep.runner import run_campaign
        from repro.sweep.spec import load_builtin_spec

        spec = load_builtin_spec("hlo_crosscheck")
        spec.refine.mode = "none"       # band is an analytic contract
        _CAMPAIGN_CACHE.append(
            run_campaign(spec, workers=0, use_cache=False))
    return _CAMPAIGN_CACHE[0]


# -- engine agreement ------------------------------------------------------

@pytest.mark.slow
def test_fast_engine_extrapolates_ingested_graph():
    from repro.sweep.refine import crosscheck_point, refine_payload

    payload = refine_payload(
        workload="hlo/qwen2_1_5b_prefill", n_tiles=2,
        hw=to_dict(resolve_preset("v5e")), compile_opts={},
        pti_ns=50_000.0, temp_c=65.0, keep_series=False, engine="fast")
    out = crosscheck_point(payload)
    assert out["extrapolated"], "28-layer ingested graph must extrapolate"
    assert out["replayed_tasks"] < out["n_tasks"] / 4
    assert out["max_interval_diff_ns"] < 1e-3
    assert out["makespan_diff_ns"] < 1e-3
    assert max(out["record_rel_diff"].values()) < 1e-9


# -- determinism (property) ------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.sampled_from(FIXTURES),
       st.sampled_from([None, 4, 6]))
def test_ingestion_deterministic(fixture, layers_keep):
    text = ingest.load_fixture(fixture)
    from repro.graph.hlo_parser import extract_tasks

    meta = ingest.fixture_meta(fixture)
    runs = [ingest.lower_tasks(
        extract_tasks(text, pod_size=int(meta.get("pod_size", 0))),
        layers_keep=layers_keep) for _ in range(2)]
    (ops_a, rep_a), (ops_b, rep_b) = runs
    assert ops_a == ops_b                       # byte-identical op table
    assert rep_a.structural_hash == rep_b.structural_hash
    assert rep_a == rep_b
    assert rep_a.structural_hash == ingest.structural_hash(ops_a)
