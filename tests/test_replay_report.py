"""HLO replay mapping, roofline MODEL_FLOPS model, Chrome-trace export,
Stack-EM task cloning — coverage for the reporting/replay layer."""
import json

import pytest

from benchmarks.roofline import model_flops
from repro.configs import REGISTRY, SHAPES
from repro.core.trace import to_chrome_trace
from repro.graph.hlo_parser import Collective, TaskSpec
from repro.graph.stackem import _clone_tasks
from repro.graph.tasks import Task
from repro.hw.mxu import GemmSpec
from repro.hw.pod import _gemm_dims, hlo_to_tasks
from repro.hw.presets import V5E
from repro.hw.chip import simulate


def test_gemm_dims_reconstruction():
    spec = _gemm_dims(flops=2 * 256 * 512 * 1024, bytes_in=0,
                      bytes_out=256 * 512 * 2)
    assert spec.m * spec.n == pytest.approx(256 * 512, rel=0.01)
    assert 2 * spec.m * spec.n * spec.k == pytest.approx(
        2 * 256 * 512 * 1024, rel=0.05)


def test_hlo_to_tasks_deps_and_streaming():
    specs = [
        TaskSpec("a", "mxu", flops=1e9, bytes_in=8 * 2**20,
                 bytes_out=8 * 2**20),
        TaskSpec("b", "vector", elems=1e6, bytes_in=1024, bytes_out=1024,
                 deps=(0,)),
        TaskSpec("c", "ici", collective=Collective(
            "all-reduce", 2**20, 16, 1, 1.0, False), deps=(1,)),
    ]
    tasks = hlo_to_tasks(specs, stream_io=True, io_threshold=2**20)
    # the big MXU task gains a DMA prologue; small vector task does not
    names = [t.name for t in tasks]
    assert "a.io" in names and "b.io" not in names
    rep = simulate(tasks, V5E)
    assert rep.makespan_ns > 0
    recs = {r.task: r for r in []}  # determinism covered elsewhere


def test_model_flops_orders():
    cfg = REGISTRY["qwen3-32b"]
    train = model_flops(cfg, SHAPES["train_4k"])
    prefill = model_flops(cfg, SHAPES["prefill_32k"])
    decode = model_flops(cfg, SHAPES["decode_32k"])
    # 6ND for train ~ 6 * 32e9 * 1M tokens
    assert train == pytest.approx(6 * cfg.param_count() * 4096 * 256,
                                  rel=0.25)
    assert decode < prefill
    # decode >= 2*N*B
    assert decode >= 2 * cfg.param_count() * 128


def test_model_flops_swa_discount():
    hy = REGISTRY["hymba-1.5b"]
    full = model_flops(hy, SHAPES["prefill_32k"])
    # a pure-full-attention config of the same size would cost more
    import dataclasses

    dense_like = dataclasses.replace(hy, sliding_window=0,
                                     global_attn_layers=(), family="dense",
                                     ssm_state=0)
    assert model_flops(dense_like, SHAPES["prefill_32k"]) > full


def test_chrome_trace_export():
    tasks = [Task("tile0.mxu", GemmSpec(m=256, n=256, k=256), name="mm")]
    from repro.hw.chip import System

    sysm = System(V5E)
    sysm.run_workload(tasks)
    trace = to_chrome_trace(sysm.tracer)
    assert any(e.get("name") == "mm" for e in trace["traceEvents"])
    json.dumps(trace)  # serializable


def test_stackem_clone_isolates_barriers():
    t = Task("tile0.mxu", GemmSpec(m=8, n=8, k=8), waits=((5, 1),),
             signals=(6,), name="x")
    c1 = _clone_tasks([t], "a")[0]
    c2 = _clone_tasks([t], "b")[0]
    assert c1.waits[0][0] != 5 and c2.waits[0][0] != 5
    assert c1.waits[0][0] != c2.waits[0][0]
    assert c1.signals[0] != c2.signals[0]
