"""Chaos suite: deterministic fault injection against the exec substrate.

The load-bearing property: under ANY seeded fault plan — crashes at
every named crash-point, torn done-file writes, heartbeat stalls, clock
skew — a spool always quiesces with every job either **done exactly
once** (record byte-identical to a fault-free run) or **quarantined
with a diagnosis**. No lost jobs, no duplicate journal events, no torn
done files surfacing as results.
"""
import json
import os
import subprocess
import sys
import tempfile
import threading
import time

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.exec import CampaignJournal, Spool, SpoolBackend, run_worker
from repro.exec.backend import BackendError, failure_record, \
    is_failure_record
from repro.exec.faults import CRASH_SITES, FaultPlan, InjectedCrash, \
    plan_scope
from repro.exec.janitor import janitor_pass, run_janitor
from repro.exec.spool import PublishError, backoff_s
from repro.sweep import RefineSpec, SweepSpec
from repro.sweep.runner import run_campaign

SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("REPRO_FAULTS", None)
    return env


# -- synthetic workload ----------------------------------------------------

N_JOBS = 6


def _payloads():
    return {f"job{i:02d}": {"key": f"job{i:02d}", "i": i}
            for i in range(N_JOBS)}


def _refine(p):
    if p.get("poison"):
        raise ValueError("poisoned payload")
    return {"out": p["i"] * 2, "echo": p["key"]}


def _golden():
    """Fault-free reference records (what every surviving done file must
    byte-match)."""
    with tempfile.TemporaryDirectory() as td:
        spool = Spool(os.path.join(td, "sp"), backoff_base_s=0.0)
        for k, p in _payloads().items():
            spool.submit(k, p)
        run_worker(spool.root, worker="golden", refine_fn=_refine,
                   spool=spool)
        return {k: spool.result(k)["record"] for k in _payloads()}


GOLDEN = _golden()


def _backdate_active(spool, age_s=1e4):
    d = os.path.join(spool.root, "active")
    old = time.time() - age_s
    for f in os.listdir(d):
        try:
            os.utime(os.path.join(d, f), (old, old))
        except FileNotFoundError:
            pass


def _chaos_drain(spool, plan, refine_fn=_refine, cycles=400):
    """Kill-loop: 'respawn' a fresh worker after every injected death,
    expiring leftover leases in between (time-warped, not slept)."""
    with plan_scope(plan):
        for c in range(cycles):
            counts = spool.counts()
            if counts["jobs"] == 0 and counts["active"] == 0:
                return c
            try:
                run_worker(spool.root, worker=f"w{c:03d}", hb_s=999.0,
                           refine_fn=refine_fn, spool=spool)
            except (InjectedCrash, RuntimeError, OSError):
                pass                   # the "SIGKILL"; respawn next cycle
            _backdate_active(spool)
            try:
                spool.reclaim()
            except OSError:
                pass                   # injected torn quarantine write
    raise AssertionError(
        f"chaos drain did not quiesce in {cycles} cycles: "
        f"{spool.counts()}")


def _check_invariants(spool, payloads, golden):
    counts = spool.counts()
    assert counts["jobs"] == 0 and counts["active"] == 0
    # "done" means a *parseable* result — a torn done file left behind
    # by a job that later terminally failed is wreckage, not a result
    done = {k for k in payloads if spool.result(k) is not None}
    failed = {k for k in payloads if spool.failure(k) is not None}
    # no lost jobs: every submitted key reached a terminal state
    assert done | failed == set(payloads)
    for k in sorted(done):
        rec = spool.result(k)["record"]
        assert json.dumps(rec, sort_keys=True) == \
            json.dumps(golden[k], sort_keys=True), k
    for k in sorted(set(payloads) - done):
        diag = spool.failure(k)
        assert diag is not None and diag.get("error"), k
    # the janitor clears any torn-done wreckage; afterwards the cheap
    # listing view agrees with the parse-everything view
    janitor_pass(spool, tmp_age_s=-1.0, corrupt_age_s=-1.0,
                 compact_age_s=None)
    assert spool.done_keys() & set(payloads) == done


# -- the chaos soak property ----------------------------------------------

_site = st.sampled_from(CRASH_SITES)
_kind = st.sampled_from(["crash", "error"])
_rate = st.floats(min_value=0.0, max_value=0.9)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6),
       st.lists(st.tuples(_site, _kind, _rate), min_size=0, max_size=4),
       st.floats(min_value=0.0, max_value=0.6),
       st.booleans())
def test_chaos_soak_exactly_once_or_quarantined(seed, crash_rules,
                                                torn_rate, stalls):
    rules = {}
    for site, kind, rate in crash_rules:
        key = (kind, site)
        rules[key] = max(rules.get(key, 0.0), rate)
    if torn_rate > 0.0:
        rules[("torn", "publish-done")] = torn_rate
    if stalls:
        rules[("stall", "heartbeat")] = 0.5
    plan = FaultPlan(seed, rules)
    with tempfile.TemporaryDirectory() as td:
        spool = Spool(os.path.join(td, "sp"), lease_s=60.0,
                      backoff_base_s=0.0)
        payloads = _payloads()
        for k, p in payloads.items():
            spool.submit(k, p)
        _chaos_drain(spool, plan)
        _check_invariants(spool, payloads, GOLDEN)


def test_chaos_soak_is_deterministic():
    """The same seeded plan produces the same terminal partition —
    injected failures are replayable inputs, not flakes."""
    plan_spec = ("7:crash@before-publish=0.55,error@mid-refine=0.35,"
                 "torn@publish-done=0.4,crash@after-publish=0.3")
    outcomes = []
    for _ in range(2):
        plan = FaultPlan.parse(plan_spec)
        with tempfile.TemporaryDirectory() as td:
            spool = Spool(os.path.join(td, "sp"), lease_s=60.0,
                          backoff_base_s=0.0)
            payloads = _payloads()
            for k, p in payloads.items():
                spool.submit(k, p)
            _chaos_drain(spool, plan)
            _check_invariants(spool, payloads, GOLDEN)
            outcomes.append((tuple(sorted(spool.done_keys())),
                             tuple(sorted(spool.failed_keys()))))
    assert outcomes[0] == outcomes[1]


def test_chaos_crash_at_every_site_single_shot(tmp_path):
    """rate-1.0 crash at each named site: the job survives through the
    retry budget (attempt-indexed redraw never lets it pass), ends
    quarantined with the budget diagnosis — except after-publish, where
    the result is already durable and must be served exactly-once."""
    for i, site in enumerate(CRASH_SITES):
        plan = FaultPlan(i, {("crash", site): 1.0})
        spool = Spool(str(tmp_path / f"sp-{site}"), lease_s=60.0,
                      backoff_base_s=0.0)
        spool.submit("k", {"key": "k", "i": 1})
        _chaos_drain(spool, plan)
        if site == "after-publish":
            assert spool.result("k")["record"] == {"out": 2, "echo": "k"}
        else:
            diag = spool.failure("k")
            assert diag and "retry budget exhausted" in diag["error"]


# -- fault-plan unit behavior ---------------------------------------------

def test_fault_plan_parse_roundtrip_and_validation():
    plan = FaultPlan.parse("42:crash@mid-refine=0.25,torn@publish-done=1")
    assert plan.seed == 42
    assert plan.rate("crash", "mid-refine") == 0.25
    assert plan.rate("torn", "publish-done") == 1.0
    assert FaultPlan.parse(plan.to_spec()).rules == plan.rules
    with pytest.raises(ValueError):
        FaultPlan.parse("no-seed-part")
    with pytest.raises(ValueError):
        FaultPlan.parse("x:crash@mid-refine=1")      # non-int seed
    with pytest.raises(ValueError):
        FaultPlan.parse("1:crash@nowhere=1")         # unknown site
    with pytest.raises(ValueError):
        FaultPlan.parse("1:gremlin@mid-refine=1")    # unknown kind
    with pytest.raises(ValueError):
        FaultPlan.parse("1:torn@mid-refine=1")       # torn needs a publish


def test_fault_decisions_are_pure_and_attempt_indexed():
    plan = FaultPlan(9, {("crash", "mid-refine"): 0.5})
    draws = [plan.fires("crash", "mid-refine", f"key{i}", 0)
             for i in range(200)]
    assert draws == [plan.fires("crash", "mid-refine", f"key{i}", 0)
                     for i in range(200)]            # pure
    assert 40 < sum(draws) < 160                     # ~rate, not const
    # a retried job redraws: some key flips between attempts
    assert any(plan.fires("crash", "mid-refine", f"key{i}", 0)
               != plan.fires("crash", "mid-refine", f"key{i}", 1)
               for i in range(50))


def test_soft_crash_is_base_exception():
    plan = FaultPlan(1, {("crash", "after-claim"): 1.0})
    with pytest.raises(InjectedCrash):
        plan.maybe_crash("after-claim", "k")
    assert not isinstance(InjectedCrash("x"), Exception)


def test_clock_skew_shifts_spool_now(tmp_path):
    spool = Spool(str(tmp_path / "sp"))
    with plan_scope(FaultPlan(0, {("skew", "clock"): 500.0})):
        assert spool._now() - time.time() > 400.0
    assert abs(spool._now() - time.time()) < 60.0


# -- release-safety regressions (the satellite crash-window fix) -----------

def test_crash_between_publish_and_release_is_recoverable(tmp_path):
    """A kill in the window between the done publish and the lease
    release leaks the lease — and reclaim must drop the stale claim
    WITHOUT re-running the job (the result is already durable)."""
    spool = Spool(str(tmp_path / "sp"), backoff_base_s=0.0)
    spool.submit("k", {"key": "k", "i": 3})
    with plan_scope(FaultPlan(1, {("crash", "after-publish"): 1.0})):
        with pytest.raises(InjectedCrash):
            run_worker(spool.root, worker="w0", refine_fn=_refine,
                       spool=spool)
    assert spool.result("k")["record"] == {"out": 6, "echo": "k"}
    assert spool.counts()["active"] == 1             # leaked, as a kill would
    _backdate_active(spool)
    assert spool.reclaim() == 0                      # dropped, not re-queued
    assert spool.counts() == {"jobs": 0, "active": 0, "done": 1,
                              "failed": 0}


def test_recoverable_error_after_publish_releases_lease(tmp_path):
    """A plain exception in the same window must release the lease
    (the pre-fix behavior leaked it until lease expiry)."""
    spool = Spool(str(tmp_path / "sp"), backoff_base_s=0.0)
    spool.submit("k", {"key": "k", "i": 2})
    with plan_scope(FaultPlan(2, {("error", "after-publish"): 1.0})):
        n = run_worker(spool.root, worker="w0", refine_fn=_refine,
                       spool=spool)
    assert n == 1                                    # counted as done
    assert spool.counts() == {"jobs": 0, "active": 0, "done": 1,
                              "failed": 0}


def test_failed_done_publish_requeues_with_backoff(tmp_path):
    """A torn done publish must requeue the job immediately (bumped
    attempts, lease released) instead of leaking the claim."""
    spool = Spool(str(tmp_path / "sp"), backoff_base_s=0.0)
    spool.submit("k", {"key": "k", "i": 1})
    with plan_scope(FaultPlan(3, {("torn", "publish-done"): 1.0})):
        job = spool.claim("w0")
        with pytest.raises(PublishError):
            spool.complete(job, {"r": 1}, wall_s=0.0)
        assert spool.counts()["active"] == 0         # released
        assert spool.counts()["jobs"] == 1           # requeued
        job2 = spool.claim("w1")
        assert job2 is not None and job2.attempts == 1
    # the torn done file never surfaced as a result, and a healthy
    # publish atomically replaces the wreckage
    spool.complete(job2, {"r": 1}, wall_s=0.0)
    assert spool.result("k")["record"] == {"r": 1}


def test_failed_fail_publish_requeues(tmp_path):
    spool = Spool(str(tmp_path / "sp"), backoff_base_s=0.0)
    spool.submit("k", {"key": "k", "i": 1})
    with plan_scope(FaultPlan(4, {("torn", "publish-fail"): 1.0})):
        job = spool.claim("w0")
        with pytest.raises(PublishError):
            spool.fail(job, "boom")
        assert spool.counts()["active"] == 0
        assert spool.counts()["jobs"] == 1
    job2 = spool.claim("w1")
    spool.fail(job2, "boom")
    assert spool.failure("k")["error"] == "boom"


# -- retry backoff ---------------------------------------------------------

def test_backoff_deterministic_jittered_capped():
    assert backoff_s("k", 0) == 0.0
    assert backoff_s("k", 1, base_s=0.0) == 0.0
    b = backoff_s("k", 1, base_s=2.0, cap_s=60.0)
    assert b == backoff_s("k", 1, base_s=2.0, cap_s=60.0)  # pure
    assert 1.5 <= b <= 2.5                                 # 2s +/- 25%
    assert backoff_s("k", 2, base_s=2.0, cap_s=60.0) > b * 1.2
    assert backoff_s("k", 50, base_s=2.0, cap_s=60.0) <= 75.0  # capped
    # distinct keys de-synchronize
    assert backoff_s("a", 1) != backoff_s("b", 1)


def test_spool_reclaim_backoff(tmp_path):
    spool = Spool(str(tmp_path / "sp"), lease_s=60.0, backoff_base_s=5.0)
    spool.submit("k", {"i": 1})
    spool.claim("dead")
    _backdate_active(spool)
    assert spool.reclaim() == 1
    with open(os.path.join(spool.root, "jobs", "k.json")) as f:
        entry = json.load(f)
    assert entry["attempts"] == 1
    assert entry["not_before"] > time.time() + 3.0
    assert spool.claim("w1") is None                 # backed off
    assert spool.counts()["jobs"] == 1               # still queued
    eta = spool.next_retry_eta()
    assert eta is not None and 3.0 < eta <= 6.5
    st_ = spool.status()
    assert st_["backed_off"] == 1 and st_["quarantined"] == 0
    assert st_["next_retry_eta_s"] == pytest.approx(eta, abs=1.0)
    # time-warp past the window (clock-skew fault = free time machine)
    with plan_scope(FaultPlan(0, {("skew", "clock"): 100.0})):
        job = spool.claim("w1")
        assert job is not None and job.attempts == 1
        spool.complete(job, {"ok": 1}, wall_s=0.0)
    assert spool.result("k")["record"] == {"ok": 1}


def test_status_counts_quarantined(tmp_path):
    spool = Spool(str(tmp_path / "sp"), lease_s=60.0, retry_budget=0,
                  backoff_base_s=0.0)
    spool.submit("poison", {"i": 1})
    spool.claim("w0")
    _backdate_active(spool)
    assert spool.reclaim() == 1                      # budget 0: quarantine
    st_ = spool.status()
    assert st_["failed"] == 1 and st_["quarantined"] == 1


# -- janitor ---------------------------------------------------------------

def test_janitor_gc_tmp_and_corrupt_done(tmp_path):
    spool = Spool(str(tmp_path / "sp"))
    old = time.time() - 3600.0
    stale_tmp = os.path.join(spool.root, "done", "tmpabc123.tmp")
    with open(stale_tmp, "w") as f:
        f.write("{}")
    os.utime(stale_tmp, (old, old))
    fresh_tmp = os.path.join(spool.root, "jobs", "tmpdef456.tmp")
    with open(fresh_tmp, "w") as f:
        f.write("{}")
    torn = os.path.join(spool.root, "done", "torn.json")
    with open(torn, "w") as f:
        f.write('{"key": "torn", "reco')
    os.utime(torn, (old, old))
    stats = janitor_pass(spool, tmp_age_s=60.0, corrupt_age_s=60.0)
    assert stats["tmp_gc"] == 1 and stats["corrupt_gc"] == 1
    assert not os.path.exists(stale_tmp) and not os.path.exists(torn)
    assert os.path.exists(fresh_tmp)                 # too young to GC


def test_janitor_compaction_preserves_results(tmp_path):
    spool = Spool(str(tmp_path / "sp"), backoff_base_s=0.0)
    for k, p in _payloads().items():
        spool.submit(k, p)
    run_worker(spool.root, worker="w0", refine_fn=_refine, spool=spool)
    done_dir = os.path.join(spool.root, "done")
    old = time.time() - 3600.0
    for f in os.listdir(done_dir):
        os.utime(os.path.join(done_dir, f), (old, old))
    stats = janitor_pass(spool, compact_age_s=60.0)
    assert stats["compacted"] == N_JOBS
    assert [f for f in os.listdir(done_dir) if f.endswith(".json")] == []
    assert os.path.exists(os.path.join(done_dir, "_compact.jsonl"))
    # results, counts, and idempotent submit all see through compaction
    assert spool.done_keys() == set(_payloads())
    assert spool.counts()["done"] == N_JOBS
    for k in _payloads():
        assert spool.result(k)["record"] == GOLDEN[k]
        assert not spool.submit(k, {"i": 0})
    # a second pass is a no-op
    assert janitor_pass(spool, compact_age_s=60.0)["compacted"] == 0


def test_detached_janitor_unstrands_dead_fleet(tmp_path):
    """The acceptance scenario, in-process: runner and workers SIGKILLed
    (leases stale, nobody polling) — a janitor alone must return the
    work to jobs/ so the next worker to attach can finish it."""
    spool = Spool(str(tmp_path / "sp"), lease_s=60.0, backoff_base_s=0.0)
    for k, p in _payloads().items():
        spool.submit(k, p)
    for _ in range(3):                               # dead fleet
        spool.claim("killed-worker")
    _backdate_active(spool)
    assert spool.counts()["active"] == 3
    journal = str(tmp_path / "j.jsonl")
    reclaimed = run_janitor(spool.root, interval_s=0.01, iterations=2,
                            journal_path=journal)
    assert reclaimed == 3
    assert spool.counts()["active"] == 0
    # janitor passes are journaled (the Perfetto janitor lane)
    view = CampaignJournal.load(journal)
    assert view.janitor_events
    assert sum(ev.get("reclaimed", 0) for ev in view.janitor_events) == 3
    from repro.obs.perfetto import trace_campaign_journal
    trace = trace_campaign_journal(journal)
    assert any(e.get("cat") == "janitor"
               for e in trace["traceEvents"] if e.get("ph") == "i")
    # a fresh worker now finishes everything
    run_worker(spool.root, worker="late", refine_fn=_refine, spool=spool)
    assert spool.done_keys() == set(_payloads())


# -- SpoolBackend stall fail-fast + graceful degradation -------------------

def test_spool_backend_stall_fails_fast_naming_root(tmp_path):
    root = str(tmp_path / "sp")
    bk = SpoolBackend(root, workers=0, poll_s=0.02, stall_s=0.3)
    t0 = time.time()
    with pytest.raises(BackendError) as ei:
        bk.refine([{"i": 1}], keys=["k1"])
    assert time.time() - t0 < 10.0                   # not timeout_s/forever
    msg = str(ei.value)
    assert "stalled" in msg and root in msg and "janitor" in msg


def _drain_thread(root, refine_fn):
    stop = threading.Event()

    def loop():
        while not stop.is_set():
            if run_worker(root, worker="tw", hb_s=0.2,
                          refine_fn=refine_fn) == 0:
                time.sleep(0.02)

    t = threading.Thread(target=loop, daemon=True)
    t.start()
    return stop, t


def test_spool_backend_allow_partial_degrades_failures(tmp_path):
    root = str(tmp_path / "sp")

    def fn(p):
        if p["i"] == 1:
            raise ValueError("bad cell")
        return {"out": p["i"]}

    stop, t = _drain_thread(root, fn)
    jpath = str(tmp_path / "j.jsonl")
    j = CampaignJournal(jpath)
    try:
        bk = SpoolBackend(root, workers=0, poll_s=0.05)
        recs = bk.refine([{"i": 0}, {"i": 1}, {"i": 2}],
                         keys=["a", "b", "c"], journal=j,
                         allow_partial=True)
    finally:
        stop.set()
        t.join(timeout=10)
    j.end({"refined": 3})
    assert [r.get("out") for r in recs] == [0, None, 2]
    assert is_failure_record(recs[1]) and "bad cell" in recs[1]["error"]
    view = CampaignJournal.load(jpath)
    assert view.counts() == {"done": 2, "failed": 1, "cached": 0,
                             "other": 0, "total": 3}
    # exactly one journal event per point — no duplicates from polling
    assert len([e for e in view.events if e.get("ev") == "point"]) == 3
    assert not view.all_done()
    assert view.all_done(allow_failed=True)


def test_spool_backend_without_allow_partial_still_aborts(tmp_path):
    root = str(tmp_path / "sp")
    stop, t = _drain_thread(
        root, lambda p: (_ for _ in ()).throw(ValueError("always")))
    try:
        bk = SpoolBackend(root, workers=0, poll_s=0.05)
        with pytest.raises(BackendError):
            bk.refine([{"i": 0}], keys=["a"])
    finally:
        stop.set()
        t.join(timeout=10)


# -- allow-partial campaigns ----------------------------------------------

def _small_spec(**kw):
    base = dict(
        name="faults_campaign",
        workloads=["mobilenet_v2"],
        preset="paper_skew",
        axes={"clock_ghz": [0.5, 1.0]},
        n_tiles=[2],
        refine=RefineSpec(mode="all"),
    )
    base.update(kw)
    return SweepSpec(**base)


def test_campaign_allow_partial_marks_failed_cells(tmp_path, monkeypatch):
    """A deliberately poisoned cell must not abort the campaign: it
    becomes a status:failed record with the error attached, and the
    summary reports coverage."""
    import repro.sweep.refine as refine_mod
    real = refine_mod.refine_point

    def poisoned(payload):
        if payload.get("hw", {}).get("clock_ghz") == 0.5:
            raise RuntimeError("injected poison cell")
        return real(payload)

    monkeypatch.setattr(refine_mod, "refine_point", poisoned)
    spec = _small_spec()
    jpath = str(tmp_path / "j.jsonl")
    res = run_campaign(spec, workers=0, use_cache=False,
                       journal_path=jpath, allow_partial=True)
    failed = [r for r in res.records if r.get("status") == "failed"]
    ok = [r for r in res.records if r.get("refined")]
    assert len(failed) == 1 and len(ok) == 1
    assert failed[0]["failed"] and not failed[0]["refined"]
    assert "injected poison cell" in failed[0]["error"]
    assert res.summary["failed"] == 1
    assert res.summary["coverage"] == pytest.approx(0.5)
    assert res.summary["failed_points"] == [failed[0]["point_id"]]
    # _best ignores degraded records
    assert res.best("time_ns")["point_id"] == ok[0]["point_id"]
    view = CampaignJournal.load(jpath)
    assert view.all_done(allow_failed=True) and not view.all_done()
    # without the flag, the same poison aborts the campaign
    with pytest.raises(RuntimeError):
        run_campaign(spec, workers=0, use_cache=False)


def test_failure_records_never_cached(tmp_path):
    from repro.exec.backend import _cache_put
    from repro.sweep.cache import ResultCache
    cache = ResultCache(str(tmp_path / "cache"))
    _cache_put(cache, "k", failure_record("boom"))
    assert cache.get("k") is None


# -- CLI -------------------------------------------------------------------

def test_exec_cli_janitor_and_status(tmp_path):
    root = str(tmp_path / "sp")
    spool = Spool(root)
    spool.submit("k1", {"i": 1})
    spool.claim("dead")
    _backdate_active(spool)
    out = subprocess.run(
        [sys.executable, "-m", "repro.exec", "janitor", root, "--once"],
        env=_env(), capture_output=True, text=True, timeout=60)
    assert out.returncode == 0, out.stderr
    assert "janitor exit: 1 jobs reclaimed" in out.stdout
    assert spool.counts()["active"] == 0
    # reclaimed job carries a retry backoff -> visible in status
    out = subprocess.run(
        [sys.executable, "-m", "repro.exec", "status", root],
        env=_env(), capture_output=True, text=True, timeout=60)
    assert out.returncode == 0, out.stderr
    rows = dict(line.split(",", 1) for line in
                out.stdout.strip().splitlines())
    assert rows["jobs"] == "1" and rows["backed_off"] == "1"
    assert float(rows["next_retry_eta_s"]) > 0.0
    assert rows["quarantined"] == "0"


def test_env_driven_fault_plan_kills_subprocess_worker(tmp_path):
    """REPRO_FAULTS makes a real subprocess worker die hard (exit 137)
    at the injected crash point — the mechanism the CI chaos lane uses."""
    root = str(tmp_path / "sp")
    spool = Spool(root)
    spool.submit("k1", {"i": 1})
    env = _env()
    env["REPRO_FAULTS"] = "1:crash@after-claim=1"
    code = ("import sys; from repro.exec.worker import run_worker; "
            "run_worker(sys.argv[1], refine_fn=lambda p: {'ok': 1})")
    out = subprocess.run([sys.executable, "-c", code, root], env=env,
                         capture_output=True, text=True, timeout=60)
    assert out.returncode == 137, (out.returncode, out.stderr)
    assert spool.counts()["active"] == 1             # lease left behind
    # without the env plan the respawned worker finishes the job
    _backdate_active(spool)
    spool2 = Spool(root, backoff_base_s=0.0)
    spool2.reclaim()
    out = subprocess.run([sys.executable, "-c", code, root], env=_env(),
                         capture_output=True, text=True, timeout=60)
    assert out.returncode == 0, out.stderr
    assert spool.result("k1")["record"] == {"ok": 1}
