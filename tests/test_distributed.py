"""Sharding rules, MoE paths, serving engine, vectorized scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import REGISTRY
from repro.core.vectorized import from_tasks, params_of, schedule_many
from repro.distributed.sharding import rules_for
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import resnet50
from repro.hw.chip import simulate
from repro.hw.presets import paper_skew
from repro.models import build_model
from repro.models.moe import moe_dense, moe_onehot, _moe_ep_local
from repro.serve.engine import ServeEngine


def _mesh(shape=(16, 16), axes=("data", "model")):
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        # older jaxlib: AbstractMesh(((name, size), ...)) pair form
        return AbstractMesh(tuple(zip(axes, shape)))


def test_rules_divisibility_head_tp():
    mesh = _mesh()
    r_yes = rules_for(mesh, n_heads=64, d_ff=25600)
    assert r_yes.table["heads"] == "model"
    assert r_yes.table["act_seq"] is None
    r_no = rules_for(mesh, n_heads=9, d_ff=1536)
    assert r_no.table["heads"] is None
    assert r_no.table["act_seq"] == "model"


def test_rules_fsdp_flag():
    mesh = _mesh()
    assert rules_for(mesh, fsdp=True).table["embed"] == "data"
    assert rules_for(mesh, fsdp=False).table["embed"] is None


def test_param_pspecs_guard():
    """Non-divisible dims are left unsharded in parameter pspecs."""
    mesh = _mesh()
    cfg = REGISTRY["smollm-135m"]       # 9 heads, kv=3
    rules = rules_for(mesh, n_heads=cfg.n_heads, d_ff=cfg.d_ff)
    model = build_model(cfg)
    specs = model.pspecs(rules)
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    tmpl = jax.tree_util.tree_leaves(
        model.template(),
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes"))
    mesh_sizes = dict(zip(("data", "model"), (16, 16)))
    for t, spec in zip(tmpl, flat):
        for dim, part in zip(t.shape, tuple(spec) + (None,) * 8):
            if part is None:
                continue
            parts = (part,) if isinstance(part, str) else part
            n = 1
            for a in parts:
                n *= mesh_sizes[a]
            assert dim % n == 0, (t.shape, spec)


@pytest.mark.slow
def test_moe_ep_local_matches_dense():
    """Single-shard EP path (no axis) == dense oracle (capacity ample)."""
    T, d, E, f, k = 16, 8, 4, 16, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wr = jax.random.normal(ks[1], (d, E)) * 0.3
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.3
    wu = jax.random.normal(ks[3], (E, d, f)) * 0.3
    wd = jax.random.normal(ks[4], (E, f, d)) * 0.3
    ref = moe_dense(x, wr, wg, wu, wd, k=k)
    got = _moe_ep_local(x, wr, wg, wu, wd, k=k, n_experts=E,
                        capacity_factor=8.0, axis_name=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_moe_onehot_matches_dense():
    T, d, E, f, k = 12, 16, 8, 32, 2
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wr = jax.random.normal(ks[1], (d, E)) * 0.2
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.2
    wu = jax.random.normal(ks[3], (E, d, f)) * 0.2
    wd = jax.random.normal(ks[4], (E, f, d)) * 0.2
    ref = moe_dense(x, wr, wg, wu, wd, k=k)
    got = moe_onehot(x, wr, wg, wu, wd, k=k, n_experts=E,
                     capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_moe_capacity_drops_tokens():
    """With capacity << demand some tokens fall back to 0 contribution."""
    T, d, E, f, k = 64, 8, 2, 8, 2
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wr = jnp.zeros((d, E))  # uniform routing -> both experts hit capacity
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.3
    wu = jax.random.normal(ks[3], (E, d, f)) * 0.3
    wd = jax.random.normal(ks[4], (E, f, d)) * 0.3
    full = moe_onehot(x, wr, wg, wu, wd, k=k, n_experts=E,
                      capacity_factor=64.0)
    tight = moe_onehot(x, wr, wg, wu, wd, k=k, n_experts=E,
                       capacity_factor=0.25)
    dropped = np.mean(np.all(np.asarray(tight) == 0.0, axis=-1))
    assert dropped > 0.2
    assert not np.allclose(np.asarray(full), np.asarray(tight))


@pytest.mark.slow
def test_serve_engine_generates_and_handles_stragglers():
    cfg = REGISTRY["smollm-135m"].reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, smax=64, jit=False, max_retries=1)
    r1 = eng.submit(np.arange(8) % cfg.vocab_size, max_new=4)
    r2 = eng.submit(np.arange(5) % cfg.vocab_size, max_new=4,
                    deadline_steps=2)  # straggler: evicted+requeued, retried
    out = eng.run(batch_size=2)
    assert len(out[r1]) == 4
    # the straggler was re-queued once, then evicted or finished
    assert r2 in out or r2 in eng.evicted
    # determinism
    eng2 = ServeEngine(model, params, smax=64, jit=False)
    r1b = eng2.submit(np.arange(8) % cfg.vocab_size, max_new=4)
    out2 = eng2.run(batch_size=1)
    assert out[r1] == out2[r1b]


def test_vectorized_scheduler_matches_event_engine():
    ops = resnet50()
    cfg = paper_skew()
    cw = compile_ops(ops, cfg, CompileOptions(n_tiles=2))
    event = simulate(cw.tasks, cfg, n_tiles=2).makespan_ns
    arrays = from_tasks(cw.tasks)
    analytic = float(schedule_many(arrays, params_of(cfg)[None])[0])
    assert 0.5 < event / analytic < 2.0


def test_vectorized_scheduler_monotone_in_clock():
    ops = resnet50()
    cfg = paper_skew()
    cw = compile_ops(ops, cfg, CompileOptions(n_tiles=1))
    arrays = from_tasks(cw.tasks)
    pm = np.stack([params_of(cfg.replace(clock_ghz=f))
                   for f in (0.3, 0.6, 0.9, 1.2)])
    res = schedule_many(arrays, pm)
    assert (np.diff(res) < 0).all()
