"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_mha
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.kernel import fused_rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssm_scan.ops import ssm_scan_batched
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def _mha_ref(q, k, v, causal):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, v.shape[1], hd)
    o = attention_ref(qf, kf, vf, n_q_heads_per_kv=G, causal=causal)
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 2, 2, 64),       # MHA
    (2, 256, 4, 2, 64),       # GQA 2:1
    (1, 384, 8, 1, 32),       # MQA, ragged seq vs block
    (2, 128, 3, 1, 128),      # odd head count
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, KV, hd, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    out = flash_mha(q, k, v, causal=causal)
    ref = _mha_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.bfloat16)
    out = flash_mha(q, k, v, causal=True).astype(jnp.float32)
    ref = _mha_ref(q, k, v, True).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)


def test_flash_attention_block_invariance():
    """Block-shape choice must not change the result."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    a = flash_mha(q, k, v, block_q=64, block_k=64)
    b = flash_mha(q, k, v, block_q=128, block_k=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("shape,dtype", [
    ((64, 256), jnp.float32),
    ((3, 50, 512), jnp.bfloat16),
    ((1, 1, 128), jnp.float32),
])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], dtype)
    out = fused_rmsnorm(x, w).astype(jnp.float32)
    ref = rmsnorm_ref(x, w).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.slow
@given(st.integers(2, 300), st.integers(1, 700))
@settings(max_examples=12, deadline=None)
def test_ssm_scan_property(S, C):
    """Property: kernel == associative-scan oracle across shapes."""
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(S), (S, C)))
    b = jax.random.normal(jax.random.PRNGKey(C), (S, C))
    out = ssm_scan_batched(a, b)
    ref = ssm_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_batched_3d():
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(0), (2, 64, 96)))
    b = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 96))
    out = ssm_scan_batched(a, b)
    ref = jax.vmap(ssm_scan_ref)(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
