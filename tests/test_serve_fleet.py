"""Serving-fleet simulator (ISSUE 6): traffic generators, continuous-
batching invariants, the analytic step-cost model, serve_grid spec
expansion, and the ServeEngine queue/eviction bugfix."""
import json

import numpy as np
import pytest

from repro.hw.presets import resolve_preset, to_dict
from repro.power.powerem import analytic_power_w, pod_power_w
from repro.serve.fleet import (FleetParams, ServeCostModel, StepCost,
                               serve_payload, simulate_fleet,
                               simulate_serve_point)
from repro.serve.traffic import (TraceRequest, bursty_trace,
                                 load_trace_jsonl, make_trace,
                                 poisson_trace)
from repro.sweep.spec import RefineSpec, SweepSpec


# -- traffic ----------------------------------------------------------------

def _gaps(trace):
    t = np.array([r.arrival_ns for r in trace])
    return np.diff(np.concatenate([[0.0], t]))


def test_poisson_trace_mean_and_determinism():
    tr = poisson_trace(rate_rps=100.0, n_requests=20_000, seed=3,
                      prompt_tokens=64, max_new=8)
    assert len(tr) == 20_000
    t = np.array([r.arrival_ns for r in tr])
    assert (np.diff(t) > 0).all()          # strictly increasing
    mean_gap_s = float(_gaps(tr).mean()) / 1e9
    assert abs(mean_gap_s - 0.01) < 0.0005  # 1/rate within 5%
    again = poisson_trace(rate_rps=100.0, n_requests=20_000, seed=3,
                          prompt_tokens=64, max_new=8)
    assert tr == again                      # seeded: bit-reproducible
    other = poisson_trace(rate_rps=100.0, n_requests=20_000, seed=4,
                          prompt_tokens=64, max_new=8)
    assert tr[0] != other[0]


def test_bursty_trace_regime_switching():
    kw = dict(rate_rps=100.0, n_requests=20_000, seed=5,
              prompt_tokens=64, max_new=8, burst_x=9.0, dwell_s=1.0)
    tr = bursty_trace(**kw)
    assert tr == bursty_trace(**kw)
    gaps = _gaps(tr)
    # long-run mean rate stays ~rate_rps
    mean_gap_s = float(gaps.mean()) / 1e9
    assert abs(mean_gap_s - 0.01) < 0.002
    # MMPP-2 is overdispersed vs Poisson: inter-arrival CV > 1 (a pure
    # exponential has CV == 1; with burst_x=9 the mixture is well above)
    cv = float(gaps.std() / gaps.mean())
    poisson_cv = float(_gaps(poisson_trace(
        rate_rps=100.0, n_requests=20_000, seed=5, prompt_tokens=64,
        max_new=8)).std() / 1e9 / 0.01)
    assert cv > 1.3 > poisson_cv * 1.2
    # both regimes actually occur: calm-rate gaps (~1/20 s) and
    # burst-rate gaps (~1/180 s) are each well represented
    assert (gaps > 0.02e9).mean() > 0.05
    assert (gaps < 0.01e9).mean() > 0.5


def test_bursty_trace_validation():
    with pytest.raises(ValueError, match="burst_x"):
        bursty_trace(rate_rps=1.0, n_requests=10, seed=0,
                     prompt_tokens=8, max_new=2, burst_x=0.5)


def test_jsonl_trace_loader(tmp_path):
    p = tmp_path / "trace.jsonl"
    rows = [{"arrival_s": 0.2, "prompt_tokens": 32, "max_new": 4},
            {"arrival_ns": 1e8, "prompt_tokens": 16, "max_new": 2}]
    p.write_text("\n".join(json.dumps(r) for r in rows) + "\n")
    tr = load_trace_jsonl(str(p))
    assert tr == [TraceRequest(1e8, 16, 2), TraceRequest(2e8, 32, 4)]
    (tmp_path / "empty.jsonl").write_text("\n")
    with pytest.raises(ValueError, match="empty"):
        load_trace_jsonl(str(tmp_path / "empty.jsonl"))
    with pytest.raises(ValueError, match="unknown traffic kind"):
        make_trace({"kind": "nope"}, prompt_tokens=8, max_new=2)


# -- fleet event loop (synthetic costs: pure scheduling semantics) ----------

class _ConstCosts:
    """Constant step costs — isolates the event loop from the model."""

    def __init__(self, prefill_ns=4e6, decode_ns=1e6):
        self.p, self.d = prefill_ns, decode_ns

    def prefill_cost(self, batch, prompt):
        return StepCost(self.p, {"mxu": self.p, "vpu": 0.0,
                                 "dma": 0.0, "ici": 0.0})

    def decode_cost(self, batch, kv):
        return StepCost(self.d, {"mxu": self.d, "vpu": 0.0,
                                 "dma": 0.0, "ici": 0.0})


def _trace(n=200, rate=100.0, seed=1, prompt=64, max_new=8):
    return poisson_trace(rate_rps=rate, n_requests=n, seed=seed,
                         prompt_tokens=prompt, max_new=max_new)


@pytest.mark.parametrize("policy", ["static", "continuous"])
def test_fleet_invariants(policy):
    p = FleetParams(replicas=2, slots=4, kv_capacity=128, policy=policy,
                    max_queue=8)
    # ~2x overload: each replica serves ~4 req / 36 ms ~= 111 rps
    res = simulate_fleet(_trace(300, rate=400.0),
                         _ConstCosts(decode_ns=4e6), p)
    by = {}
    for r in res.requests:
        by.setdefault(r.status, []).append(r)
    # conservation: submitted == completed + evicted + rejected
    assert set(by) <= {"done", "evicted", "rejected"}
    assert sum(len(v) for v in by.values()) == 300
    # max_queue=8 at ~4x overload forces admission-control rejections
    assert by.get("done") and by.get("rejected")
    # no slot oversubscription, occupancy a valid fraction
    assert res.max_active <= p.slots
    assert 0.0 < res.slot_ns <= res.capacity_ns
    for r in by.get("done", []) + by.get("evicted", []):
        # TTFT >= queue wait: arrival <= admission <= first token
        assert r.arrival_ns <= r.admit_ns < r.first_ns <= r.done_ns
        assert 1 <= r.tokens <= r.max_new


def test_fleet_kv_pressure_evicts_mid_decode():
    # prompt 64 into kv_capacity 70: every sequence hits the KV ceiling
    # after exactly 6 generated tokens and is evicted with its partial
    p = FleetParams(replicas=1, slots=4, kv_capacity=70,
                    policy="continuous")
    res = simulate_fleet(_trace(50, rate=100.0), _ConstCosts(), p)
    assert all(r.status == "evicted" and r.tokens == 6
               for r in res.requests)


def test_continuous_beats_static_ttft_under_load():
    """In the decode-dominated regime (long generations, cheap prefill)
    interleaving prefills into the running batch slashes tail TTFT:
    static batching makes every arrival wait for a full batch drain."""
    def p99_ttft(policy):
        p = FleetParams(replicas=1, slots=4, kv_capacity=1024,
                        policy=policy)
        res = simulate_fleet(
            _trace(400, rate=40.0, max_new=64),
            _ConstCosts(prefill_ns=1e6, decode_ns=1e6), p)
        done = [r for r in res.requests if r.status == "done"]
        assert len(done) == 400
        return np.percentile([r.first_ns - r.arrival_ns for r in done],
                             99)

    assert p99_ttft("continuous") < p99_ttft("static")


def test_fleet_record_rollup():
    p = FleetParams(replicas=1, slots=4, kv_capacity=1024,
                    policy="continuous")
    res = simulate_fleet(_trace(100, rate=50.0), _ConstCosts(), p)
    rec = res.record(slo_ttft_ms=1e9, slo_tpot_ms=1e9)  # everything ok
    assert rec["completed"] == 100 and rec["requests"] == 100
    assert rec["goodput_rps"] == rec["throughput_rps"] > 0
    assert rec["slo_attainment"] == 1.0
    assert rec["ttft_p50_ms"] <= rec["ttft_p95_ms"] <= rec["ttft_p99_ms"]
    tight = res.record(slo_ttft_ms=1e-6, slo_tpot_ms=1e-6)  # nothing ok
    assert tight["goodput_rps"] == 0.0 and tight["slo_attainment"] == 0.0


def test_fleet_params_validation():
    with pytest.raises(ValueError, match="policy"):
        FleetParams(policy="mystery")
    with pytest.raises(ValueError, match="fleet shape"):
        FleetParams(slots=0)


# -- analytic step-cost model ----------------------------------------------

def test_cost_model_buckets_and_monotonicity():
    cfg = resolve_preset("v5e")
    cm = ServeCostModel(cfg, arch="qwen3-32b", layers=2, tp=2, n_tiles=2)
    # power-of-two bucketing memoizes: batch 3 and 4 share a compile
    assert cm.decode_cost(3, 64) is cm.decode_cost(4, 64)
    assert cm.prefill_cost(1, 63) is cm.prefill_cost(1, 64)
    # longer KV context costs more; more concurrent sequences cost more
    assert cm.decode_cost(4, 64).ns < cm.decode_cost(4, 4096).ns
    assert cm.decode_cost(1, 64).ns < cm.decode_cost(16, 64).ns
    # busy time is per engine class and positive for the compute classes
    c = cm.decode_cost(4, 64)
    assert set(c.busy) == {"mxu", "vpu", "dma", "ici"}
    assert c.busy["mxu"] > 0 and c.busy["dma"] > 0


def test_serve_point_end_to_end():
    cfg = resolve_preset("v5e")
    pl = serve_payload(
        workload="serve/test", arch="qwen3-32b", layers=2, prompt=64,
        max_new=8, tp=2, ep=1, dp=2, pod=0, slots=4, kv_capacity=128,
        policy="continuous",
        traffic={"kind": "poisson", "rate_rps": 50.0, "n_requests": 80,
                 "seed": 7},
        slo={"ttft_ms": 500.0, "tpot_ms": 50.0}, n_tiles=2,
        hw=to_dict(cfg), temp_c=60.0)
    rec = simulate_serve_point(pl)
    assert rec["serve"] is True and rec["chips"] == 4
    assert rec["completed"] + rec["evicted"] + rec["rejected"] == 80
    assert rec["avg_w"] > 0 and rec["energy_j"] > 0
    assert rec["decode_step_ns"] > 0 < rec["prefill_step_ns"]
    # kind dispatch: the generic refinement entrypoint routes here
    from repro.sweep.refine import refine_point
    assert refine_point(pl) == rec


def test_pod_power_scales_linearly():
    cfg = resolve_preset("v5e")
    util = {"mxu": 0.5, "vpu": 0.2, "vmem": 0.5, "hbm": 0.7,
            "dma": 0.7, "ici": 0.1, "noc": 0.1}
    one = analytic_power_w(cfg, util, n_tiles=2)
    assert pod_power_w(cfg, util, chips=6, n_tiles=2) == \
        pytest.approx(6 * one)
    with pytest.raises(ValueError, match="chips"):
        pod_power_w(cfg, util, chips=0)


# -- serve_grid spec expansion ---------------------------------------------

def _grid(**over):
    g = {"arch": "qwen3-32b", "layers": 2, "prompt": 64, "max_new": 8,
         "kv_capacity": 128, "tp": [1, 2], "policy": "continuous",
         "traffic": "poisson", "rate_rps": [10, 20], "n_requests": 50,
         "slo": {"ttft_ms": 500.0, "tpot_ms": 50.0}}
    g.update(over)
    return g


def test_serve_grid_expansion_and_names():
    spec = SweepSpec(name="s", serve_grid=_grid(), preset="v5e",
                     refine=RefineSpec(mode="all"))
    pts = spec.serve_points()
    assert len(pts) == 4 == spec.grid_size     # tp x rate
    assert pts[0].workload == \
        "serve/qwen3-32b/L2/p64g8tp1dp1/s8kv128/continuous/poisson@r10"
    assert pts[0].overrides["rate_rps"] == 10.0
    assert {p.point_id() for p in pts} == \
        {p.point_id() for p in pts}            # ids unique per point
    assert len({p.point_id() for p in pts}) == 4
    # serialization round-trip is idempotent (runner re-expands)
    spec2 = SweepSpec.from_dict(spec.to_dict())
    assert [p.workload for p in spec2.serve_points()] == \
        [p.workload for p in pts]


def test_serve_grid_validation():
    with pytest.raises(KeyError, match="missing"):
        SweepSpec(name="s", serve_grid={"arch": "qwen3-32b"})
    with pytest.raises(KeyError, match="unknown serve_grid keys"):
        SweepSpec(name="s", serve_grid=_grid(surprise=1))
    with pytest.raises(ValueError, match="policy"):
        SweepSpec(name="s", serve_grid=_grid(policy="fifo"))
    with pytest.raises(KeyError, match="trace_path"):
        SweepSpec(name="s", serve_grid=_grid(traffic="jsonl"))
    with pytest.raises(KeyError, match="MoE|moe"):
        SweepSpec(name="s", serve_grid=_grid(ep=4))   # dense arch
    # a serve-only spec needs no workloads...
    SweepSpec(name="s", serve_grid=_grid())
    # ...but an empty spec still fails
    with pytest.raises(ValueError, match="needs workloads"):
        SweepSpec(name="s")


# -- ServeEngine bugfix: deque drain + evicted partial output ---------------

_V = 16


class _CountingModel:
    """Deterministic jax-free stand-in: next token = last token + 1."""

    @staticmethod
    def _onehot(idx):
        out = np.zeros((len(idx), _V), np.float32)
        out[np.arange(len(idx)), idx] = 1.0
        return out

    def prefill(self, params, batch, smax):
        toks = np.asarray(batch["tokens"])
        return self._onehot((toks[:, -1] + 1) % _V), None

    def decode_step(self, params, cache, tokens):
        t = np.asarray(tokens)[:, 0]
        return self._onehot((t + 1) % _V), cache


def test_serve_engine_deque_and_evicted_partials():
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(_CountingModel(), params=None, smax=8, jit=False,
                      max_retries=0)
    r1 = eng.submit(np.array([3], np.int32), max_new=4)
    r2 = eng.submit(np.array([7], np.int32), max_new=4,
                    deadline_steps=2)   # straggler, no retry budget
    r3 = eng.submit(np.array([11], np.int32), max_new=2)
    out = eng.run(batch_size=2)
    assert out[r1] == [4, 5, 6, 7]
    assert out[r3] == [12, 13]
    # the permanently-evicted straggler surfaces its partial output
    # instead of silently discarding it (and stays flagged as evicted)
    assert r2 in eng.evicted
    assert out[r2] == [8, 9]
    # O(n) drain: the queue is a deque now (regression guard for the
    # list.pop(0) quadratic drain)
    from collections import deque
    assert isinstance(eng.queue, deque)
