"""Hardware component models: MXU pipeline, vec characterization, HBM
paging, DMA descriptor splitting/compression, ICI collectives."""
import pytest

from repro.core import Environment, Tracer
from repro.hw.dma import Dma, DmaDescriptor
from repro.hw.ici import CollectiveSpec, IciFabric
from repro.hw.memory import Hbm, VMem
from repro.hw.mxu import GemmSpec, Mxu, choose_block
from repro.hw.presets import V5E, paper_skew
from repro.hw.vecunit import VecSpec, VecUnit, fit_table


def _system_bits(cfg):
    env = Environment()
    tr = Tracer()
    vmem = VMem(env, cfg, tr)
    return env, tr, vmem


def test_choose_block_fits_budget_and_aligns():
    cfg = V5E
    spec = GemmSpec(m=4096, n=8192, k=4096)
    bm, bn, bk = choose_block(spec, cfg)
    ws = bm * bk * 2 + bk * bn * 2 + bm * bn * 4
    assert ws <= cfg.vmem_block_budget
    assert bm % cfg.mxu_rows == 0 and bn % cfg.mxu_cols == 0 and bk % 128 == 0


def test_mxu_time_near_ideal_for_big_gemm():
    cfg = V5E
    env, tr, vmem = _system_bits(cfg)
    mxu = Mxu(env, cfg, vmem, tr)
    spec = GemmSpec(m=4096, n=4096, k=4096)
    done = env.process(mxu.run(spec))
    env.run(done)
    ideal = spec.flops / (cfg.peak_tflops * 1e12) * 1e9
    assert ideal <= env.now <= 3.0 * ideal


def test_mxu_ragged_underutilization():
    """Fig 5 mechanism: tiny M wastes systolic rows -> worse efficiency."""
    cfg = V5E

    def eff(m):
        env, tr, vmem = _system_bits(cfg)
        mxu = Mxu(env, cfg, vmem, tr)
        spec = GemmSpec(m=m, n=2048, k=2048)
        done = env.process(mxu.run(spec))
        env.run(done)
        ideal = spec.flops / (cfg.peak_tflops * 1e12) * 1e9
        return ideal / env.now

    assert eff(8) < 0.25 * eff(2048)


def test_mxu_pipeline_overlap():
    """4-stage pipeline: many blocks take far less than serial sum."""
    cfg = V5E
    env, tr, vmem = _system_bits(cfg)
    mxu = Mxu(env, cfg, vmem, tr)
    spec = GemmSpec(m=4096, n=4096, k=512)
    done = env.process(mxu.run(spec))
    env.run(done)
    mac_busy = tr.busy_time("mxu")
    vmem_busy = tr.busy_time("vmem")
    assert env.now < 0.9 * (mac_busy + vmem_busy)  # stages overlap


def test_vec_characterization_fit():
    """The MoviSim-stand-in fit recovers a known (offset,a,b,c) model."""
    lane = 1024
    true = dict(offset=40.0, a=22.0, b=3.0, c=6.0)
    samples = []
    for n in (100, 1024, 5000, 8192, 65536, 100000, 123457):
        vectors = n // lane
        scalars = n - vectors * lane
        blocks = vectors // 8
        rem = vectors - blocks * 8
        cycles = (true["offset"] + true["a"] * blocks + true["b"] * rem
                  + true["c"] * scalars)
        samples.append((n, cycles))
    k = fit_table(samples, lane)
    assert k.offset == pytest.approx(true["offset"], rel=0.05)
    assert k.a == pytest.approx(true["a"], rel=0.05)
    assert k.c == pytest.approx(true["c"], rel=0.05)


def test_vecunit_kind_costs_differ():
    cfg = V5E
    env, tr, vmem = _system_bits(cfg)
    vpu = VecUnit(env, cfg, vmem, tr)
    n = 1 << 20
    t_add = vpu.ideal_time_ns(VecSpec(n_elems=n, kind="add"))
    t_tanh = vpu.ideal_time_ns(VecSpec(n_elems=n, kind="tanh"))
    assert t_tanh > 2 * t_add


def test_hbm_page_policy():
    """Open-page sequential streaming beats closed-page (row hits)."""

    def run(policy):
        cfg = paper_skew(hbm_page_policy=policy)
        env = Environment()
        tr = Tracer()
        hbm = Hbm(env, cfg, tr)

        def seq():
            for i in range(64):
                yield from hbm.access(i * 256, 256)

        p = env.process(seq())
        env.run(p)
        return env.now, hbm.row_hits

    t_open, hits_open = run("open")
    t_closed, hits_closed = run("closed")
    assert hits_open > hits_closed
    assert t_open < t_closed


def test_dma_descriptor_split_and_channels():
    cfg = V5E
    env = Environment()
    tr = Tracer()
    hbm = Hbm(env, cfg, tr)
    vmem = VMem(env, cfg, tr)
    dma = Dma(env, cfg, hbm, vmem, tr)
    d = DmaDescriptor(nbytes=8 * 2**20, contiguous_run=1 << 20)
    assert len(dma._requests(d)) == 8
    done = env.process(dma.run(d))
    env.run(done)
    # multi-channel: faster than serial per-request sum
    assert env.now < 8 * (cfg.dma_desc_overhead_ns
                          + hbm.stream_time_ns(1 << 20)) * 0.9


def test_dma_compression_reduces_time():
    cfg = V5E.replace(dma_compression=True)
    env = Environment()
    tr = Tracer()
    hbm = Hbm(env, cfg, tr)
    vmem = VMem(env, cfg, tr)
    dma = Dma(env, cfg, hbm, vmem, tr)
    raw = dma.ideal_time_ns(DmaDescriptor(nbytes=64 * 2**20))
    comp = dma.ideal_time_ns(DmaDescriptor(nbytes=64 * 2**20,
                                           compressed=True))
    assert comp < raw


@pytest.mark.parametrize("op,factor", [
    ("all-reduce", 2.0), ("all-gather", 1.0), ("reduce-scatter", 1.0)])
def test_collective_link_bytes(op, factor):
    spec = CollectiveSpec(op=op, payload_bytes=1024, group_size=16)
    assert spec.link_bytes() == pytest.approx(factor * 1024 * 15 / 16)


def test_ici_vs_dcn():
    cfg = V5E
    env = Environment()
    tr = Tracer()
    fab = IciFabric(env, cfg, tr)
    intra = fab.ideal_time_ns(CollectiveSpec("all-reduce", 2**20, 16))
    cross = fab.ideal_time_ns(CollectiveSpec("all-reduce", 2**20, 16,
                                             cross_pod=True))
    assert cross > intra
