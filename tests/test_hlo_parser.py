"""HLO parser: trip counts, dot flops, replica groups, task extraction.

The trip-count test builds a scan-vs-unrolled pair on the fly and checks
the parser's trip-aware totals against XLA's own cost_analysis of the
UNROLLED module (which needs no trip accounting).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph.hlo_parser import (decode_replica_groups, extract_tasks,
                                    parse_module, summarize)

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                   "artifacts", "dryrun")


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_vs_unrolled():
    L, M = 12, 128
    w = jax.ShapeDtypeStruct((L, M, M), jnp.float32)
    x = jax.ShapeDtypeStruct((8, M), jnp.float32)

    def f_scan(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    def f_unroll(x, w):
        for i in range(L):
            x = jnp.tanh(x @ w[i])
        return x

    s_scan = summarize(_hlo(f_scan, x, w))
    s_unroll = summarize(_hlo(f_unroll, x, w))
    expected = 2.0 * 8 * M * M * L
    assert s_scan.dot_flops == pytest.approx(expected, rel=0.01)
    assert s_unroll.dot_flops == pytest.approx(expected, rel=0.01)
    # cross-check against XLA's analysis of the unrolled module
    ca = jax.jit(f_unroll).lower(x, w).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jaxlib: one dict per device
        ca = ca[0]
    assert s_unroll.dot_flops == pytest.approx(ca["flops"], rel=0.05)


def test_dot_flops_with_batch_dims():
    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)

    def f(a, b):
        return jax.lax.dot_general(a, b, (((2,), (1,)), ((0,), (0,))))

    s = summarize(_hlo(f, a, b))
    assert s.dot_flops == pytest.approx(2 * 4 * 64 * 16 * 32, rel=0.01)


def test_replica_groups_decoding():
    g = decode_replica_groups("replica_groups=[128,2]<=[256]")
    assert g.shape == (128, 2)
    assert list(g[0]) == [0, 1] and list(g[1]) == [2, 3]
    g2 = decode_replica_groups("replica_groups=[16,16]<=[16,16]T(1,0)")
    assert g2.shape == (16, 16)
    assert list(g2[0][:3]) == [0, 16, 32]      # transposed iota
    g3 = decode_replica_groups("replica_groups={{0,8},{1,9}}")
    assert g3.shape == (2, 2) and list(g3[1]) == [1, 9]


def test_cross_pod_detection():
    g = decode_replica_groups("replica_groups=[2,256]<=[512]")
    pods = g // 256
    assert bool(np.any(pods.max(axis=1) != pods.min(axis=1))) is False
    g2 = decode_replica_groups("replica_groups=[256,2]<=[2,256]T(1,0)")
    pods2 = g2 // 256
    assert bool(np.any(pods2.max(axis=1) != pods2.min(axis=1))) is True


def test_parse_module_structure():
    def f(x):
        return jnp.sum(x * 2.0)

    text = _hlo(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    mod = parse_module(text)
    assert mod.entry in mod.computations
    entry = mod.computations[mod.entry]
    assert any(i.opcode in ("fusion", "reduce", "multiply")
               for i in entry.instrs)


def test_extract_tasks_dag():
    L, M = 4, 64

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    text = _hlo(f, jax.ShapeDtypeStruct((8, M), jnp.float32),
                jax.ShapeDtypeStruct((L, M, M), jnp.float32))
    tasks = extract_tasks(text)
    mxu = [t for t in tasks if t.engine == "mxu"]
    assert len(mxu) == L                       # one dot per unrolled trip
    # deps are acyclic and in-range
    for i, t in enumerate(tasks):
        assert all(0 <= d < i + 1 for d in t.deps)


# -- regression: gaps ingestion hit (synthetic HLO — CPU-compiled modules
# -- never carry async -start collectives or exotic dtypes) ---------------

_ASYNC_AR = """\
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024] parameter(0)
  %ar = (f32[1024], f32[1024]) all-reduce-start(%p0), replica_groups=[4,2]<=[8], to_apply=%add
  ROOT %done = f32[1024] all-reduce-done(%ar)
}
"""


def test_all_reduce_start_payload_not_double_counted():
    """An async ``-start`` op types its output as a tuple carrying BOTH
    the operand alias and the result — naive output-byte accounting
    counts the 4 KiB payload twice. The payload must equal the operand
    bytes and the ``-done`` half must contribute nothing."""
    s = summarize(_ASYNC_AR)
    assert len(s.collectives) == 1
    c = s.collectives[0]
    assert c.op == "all-reduce"                  # -start suffix stripped
    assert c.payload_bytes == 1024 * 4           # NOT 2x
    assert c.group_size == 2
    # hbm side: operand read + effective output write, not the tuple
    assert s.hbm_bytes == 2 * 1024 * 4

    tasks = extract_tasks(_ASYNC_AR)
    ici = [t for t in tasks if t.engine == "ici"]
    assert len(ici) == 1                         # -done emits no task
    assert ici[0].collective.payload_bytes == 1024 * 4
    assert ici[0].bytes_out == 1024 * 4


def test_all_gather_start_payload():
    text = _ASYNC_AR.replace(
        "(f32[1024], f32[1024]) all-reduce-start(%p0), "
        "replica_groups=[4,2]<=[8], to_apply=%add",
        "(f32[1024], f32[4096]) all-gather-start(%p0), "
        "replica_groups=[2,4]<=[8], dimensions={0}").replace(
        "f32[1024] all-reduce-done", "f32[4096] all-gather-done")
    s = summarize(text)
    assert len(s.collectives) == 1
    # gather output is genuinely larger than the operand: payload is the
    # de-aliased output (4096 elems), not operand + output
    assert s.collectives[0].payload_bytes == 4096 * 4
    assert s.collectives[0].group_size == 4


def test_sync_all_reduce_unchanged():
    """Non-start collectives (bare array output) keep exact payloads —
    the de-aliasing is a no-op for them."""
    text = _ASYNC_AR.replace(
        "(f32[1024], f32[1024]) all-reduce-start(%p0)",
        "f32[1024] all-reduce(%p0)").replace(
        "f32[1024] all-reduce-done(%ar)", "f32[1024] negate(%ar)")
    s = summarize(text)
    assert s.collectives[0].payload_bytes == 1024 * 4


def test_unknown_dtype_warns_once():
    import warnings as w

    from repro.graph import hlo_parser

    text = _ASYNC_AR.replace("f32[1024]", "f4e2m1[1024]")
    hlo_parser._WARNED_DTYPES.discard("f4e2m1")
    with w.catch_warnings(record=True) as rec:
        w.simplefilter("always")
        summarize(text)
        first = [x for x in rec if "f4e2m1" in str(x.message)]
    assert len(first) == 1                       # once, not per shape
    assert "DTYPE_BYTES" in str(first[0].message)
    with w.catch_warnings(record=True) as rec2:
        w.simplefilter("always")
        summarize(text)
    assert not [x for x in rec2 if "f4e2m1" in str(x.message)]


def test_known_dtypes_do_not_warn():
    import warnings as w

    with w.catch_warnings():
        w.simplefilter("error")
        summarize(_ASYNC_AR)


@pytest.mark.skipif(not os.path.isdir(ART), reason="no dry-run artifacts")
def test_artifact_sanity():
    import gzip
    import json

    f = os.path.join(ART, "smollm-135m__train_4k__pod16x16")
    if not os.path.exists(f + ".json"):
        pytest.skip("smollm artifact missing")
    cell = json.load(open(f + ".json"))
    if cell.get("status") != "ok":
        pytest.skip("cell not ok")
    text = gzip.open(f + ".hlo.txt.gz", "rt").read()
    s = summarize(text, pod_size=256)
    # trip-aware flops must exceed XLA's scan-blind count
    assert s.dot_flops > 2 * cell["cost_analysis"]["flops"]
    # 6ND per-chip lower bound (param_count from the config)
    n, d = cell["param_count"], 4096 * 256
    assert s.dot_flops > 6 * n * d / 256 * 0.8
    assert s.collective_bytes() > 0
