"""Event kernel: ordering, determinism, conditions, resources (paper §3.1)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AllOf, AnyOf, Container, Environment, Event,
                        Interrupt, PriorityItem, PriorityStore, Resource,
                        Store)


def test_timeout_ordering():
    env = Environment()
    log = []

    def proc(delay, tag):
        yield env.timeout(delay)
        log.append((env.now, tag))

    env.process(proc(5, "b"))
    env.process(proc(1, "a"))
    env.process(proc(5, "c"))  # same time as b: insertion order preserved
    env.run()
    assert log == [(1, "a"), (5, "b"), (5, "c")]


def test_process_return_value_and_event_chain():
    env = Environment()

    def inner():
        yield env.timeout(3)
        return 42

    def outer():
        val = yield env.process(inner())
        return val * 2

    p = env.process(outer())
    env.run()
    assert p.value == 84
    assert env.now == 3


def test_all_of_any_of():
    env = Environment()
    results = {}

    def waiter():
        ev = AnyOf(env, [env.timeout(10, "slow"), env.timeout(2, "fast")])
        vals = yield ev
        results["any"] = (env.now, vals)
        ev2 = AllOf(env, [env.timeout(1), env.timeout(4)])
        yield ev2
        results["all_t"] = env.now

    env.process(waiter())
    env.run()
    assert results["any"][0] == 2 and "fast" in results["any"][1]
    assert results["all_t"] == 2 + 4


def test_interrupt():
    env = Environment()
    caught = []

    def victim():
        try:
            yield env.timeout(100)
        except Interrupt as e:
            caught.append((env.now, e.cause))

    def attacker(p):
        yield env.timeout(7)
        p.interrupt("preempt")

    p = env.process(victim())
    env.process(attacker(p))
    env.run()
    assert caught == [(7, "preempt")]


def test_run_until_time():
    env = Environment()

    def ticker():
        while True:
            yield env.timeout(10)

    env.process(ticker())
    env.run(until=35)
    assert env.now == 35


def test_store_fifo_and_backpressure():
    env = Environment()
    store = Store(env, capacity=2)
    got, put_times = [], []

    def producer():
        for i in range(4):
            yield store.put(i)
            put_times.append(env.now)

    def consumer():
        while len(got) < 4:
            yield env.timeout(5)
            item = yield store.get()
            got.append(item)

    env.process(producer())
    env.process(consumer())
    env.run()
    assert got == [0, 1, 2, 3]          # FIFO order
    assert put_times[0] == 0 and put_times[1] == 0
    assert put_times[2] == 5            # blocked until a get freed a slot


def test_priority_store():
    env = Environment()
    ps = PriorityStore(env)
    out = []

    def run():
        yield ps.put(PriorityItem(3, "low"))
        yield ps.put(PriorityItem(1, "high"))
        yield ps.put(PriorityItem(2, "mid"))
        for _ in range(3):
            item = yield ps.get()
            out.append(item.item)

    env.process(run())
    env.run()
    assert out == ["high", "mid", "low"]


def test_container_blocking():
    env = Environment()
    c = Container(env, capacity=10, init=0)
    log = []

    def taker():
        yield c.get(6)
        log.append(("got", env.now))

    def giver():
        yield env.timeout(4)
        yield c.put(6)

    env.process(taker())
    env.process(giver())
    env.run()
    assert log == [("got", 4)]


def test_resource_mutual_exclusion():
    env = Environment()
    r = Resource(env, capacity=1)
    spans = []

    def user(tag):
        req = r.request()
        yield req
        t0 = env.now
        yield env.timeout(10)
        r.release(req)
        spans.append((tag, t0, env.now))

    env.process(user("a"))
    env.process(user("b"))
    env.run()
    # non-overlapping
    assert spans[0][2] <= spans[1][1]


@given(st.lists(st.floats(min_value=0, max_value=1e6, allow_nan=False),
                min_size=1, max_size=40))
@settings(max_examples=50, deadline=None)
def test_determinism_property(delays):
    """Identical inputs -> identical completion traces (paper determinism)."""

    def run_once():
        env = Environment()
        log = []

        def proc(d, tag):
            yield env.timeout(d)
            log.append((env.now, tag))

        for i, d in enumerate(delays):
            env.process(proc(d, i))
        env.run()
        return log

    assert run_once() == run_once()


def test_yield_non_event_fails():
    env = Environment()

    def bad():
        yield 42

    env.process(bad())
    with pytest.raises(TypeError):
        env.run()
