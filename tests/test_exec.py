"""Execution-service subsystem: spool lifecycle (claim exclusivity,
lease expiry/reclamation, kill-and-resume), journal, backends."""
import json
import os
import subprocess
import sys
import threading
import time

import pytest

from repro.exec import (CampaignJournal, InlineBackend, Spool, SpoolBackend,
                        get_backend, run_worker)
from repro.exec.backend import BackendError
from repro.sweep import RefineSpec, SweepSpec
from repro.sweep.runner import run_campaign

SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _small_spec(**kw):
    base = dict(
        name="exec_campaign",
        workloads=["mobilenet_v2"],
        preset="paper_skew",
        axes={"clock_ghz": [0.5, 1.0]},
        n_tiles=[2],
        refine=RefineSpec(mode="all"),
    )
    base.update(kw)
    return SweepSpec(**base)


# -- spool primitives ------------------------------------------------------

def test_spool_submit_idempotent(tmp_path):
    spool = Spool(str(tmp_path / "sp"))
    assert spool.submit("k1", {"x": 1})
    assert not spool.submit("k1", {"x": 1})       # already pending
    assert spool.counts() == {"jobs": 1, "active": 0, "done": 0,
                              "failed": 0}
    job = spool.claim("w0")
    assert job.key == "k1" and job.payload == {"x": 1}
    assert not spool.submit("k1", {"x": 1})       # already claimed
    spool.complete(job, {"y": 2}, wall_s=0.1)
    assert not spool.submit("k1", {"x": 1})       # already done
    assert spool.result("k1")["record"] == {"y": 2}
    assert spool.result("k1")["worker"] == "w0"


def test_spool_claim_exclusive_under_concurrency(tmp_path):
    """Many threads racing claim(): every job is claimed exactly once."""
    spool = Spool(str(tmp_path / "sp"))
    n_jobs, n_workers = 40, 8
    for i in range(n_jobs):
        spool.submit(f"job{i:03d}", {"i": i})
    claims = {w: [] for w in range(n_workers)}

    def drain(w):
        s = Spool(str(tmp_path / "sp"))
        while True:
            job = s.claim(f"w{w}")
            if job is None:
                break
            claims[w].append(job.key)

    threads = [threading.Thread(target=drain, args=(w,))
               for w in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    all_claims = [k for ks in claims.values() for k in ks]
    assert len(all_claims) == n_jobs
    assert len(set(all_claims)) == n_jobs          # no double-claims
    assert spool.counts()["jobs"] == 0


def test_spool_lease_expiry_and_reclaim(tmp_path):
    # backoff_base_s=0: this test re-claims immediately after reclaim
    # (backoff behavior is covered by test_spool_reclaim_backoff)
    spool = Spool(str(tmp_path / "sp"), lease_s=60.0, backoff_base_s=0.0)
    spool.submit("k1", {"x": 1})
    job = spool.claim("dead-worker")
    assert spool.claim("w2") is None               # queue drained
    # a live heartbeat keeps the lease
    assert job.heartbeat()
    assert spool.reclaim() == 0
    # backdate the heartbeat past the lease -> reclaimed
    old = time.time() - 120.0
    os.utime(job.active_path, (old, old))
    assert spool.reclaim() == 1
    job2 = spool.claim("w2")
    assert job2 is not None and job2.key == "k1"
    # the dead worker finishing late must not clobber anything: its
    # release is a no-op (file moved), w2's completion wins
    spool.complete(job2, {"by": "w2"}, wall_s=0.0)
    assert spool.result("k1")["record"] == {"by": "w2"}


def test_spool_claim_restarts_lease_clock(tmp_path):
    """Claiming a job file older than the lease (a resumed spool) must
    not leave the claim instantly reclaimable: rename preserves the old
    mtime, so claim() restarts the lease clock explicitly."""
    spool = Spool(str(tmp_path / "sp"), lease_s=60.0)
    spool.submit("k1", {"x": 1})
    old = time.time() - 3600.0
    os.utime(os.path.join(spool.root, "jobs", "k1.json"), (old, old))
    job = spool.claim("w0")
    assert job is not None
    assert spool.reclaim() == 0                    # lease began at claim
    spool.complete(job, {}, wall_s=0.0)
    # once done, a stale duplicate in jobs/ is dropped at claim time
    with open(os.path.join(spool.root, "jobs", "k1.json"), "w") as f:
        json.dump({"key": "k1", "payload": {"x": 1}}, f)
    assert spool.claim("w1") is None
    assert spool.counts()["jobs"] == 0


def test_spool_reclaim_skips_finished_jobs(tmp_path):
    """A worker that completed but died before releasing its claim must
    not cause re-execution: reclaim drops the stale claim."""
    spool = Spool(str(tmp_path / "sp"))
    spool.submit("k1", {"x": 1})
    job = spool.claim("w0")
    # complete without releasing (simulates dying between the two steps)
    from repro.exec.spool import _publish
    _publish(os.path.join(spool.root, "done"), "k1",
             {"key": "k1", "record": {"r": 1}, "worker": "w0",
              "wall_s": 0.0, "t_done": 0.0})
    old = time.time() - 1e4
    os.utime(job.active_path, (old, old))
    assert spool.reclaim() == 0                    # dropped, not requeued
    assert spool.counts()["jobs"] == 0
    assert spool.result("k1")["record"] == {"r": 1}


def test_spool_torn_job_file_fails_fast(tmp_path):
    """A corrupt job file must surface as a failure (so a waiting
    backend errors out instead of hanging), and not block other jobs."""
    spool = Spool(str(tmp_path / "sp"))
    with open(os.path.join(spool.root, "jobs", "bad.json"), "w") as f:
        f.write('{"key": "bad", "payl')          # torn mid-write
    spool.submit("good", {"x": 1})
    keys = []
    while True:
        job = spool.claim("w0")
        if job is None:
            break
        keys.append(job.key)
        spool.complete(job, {}, wall_s=0.0)
    assert keys == ["good"]
    assert spool.counts()["jobs"] == 0
    assert "corrupt" in spool.failure("bad")["error"]
    assert spool.submit("bad", {"x": 2})         # retriable


def test_spool_failed_job_is_retried_on_resubmit(tmp_path):
    spool = Spool(str(tmp_path / "sp"))
    spool.submit("k1", {"x": 1})
    job = spool.claim("w0")
    spool.fail(job, "boom")
    assert spool.failure("k1")["error"] == "boom"
    assert spool.submit("k1", {"x": 1})            # retry clears failure
    assert spool.failure("k1") is None
    assert spool.counts()["jobs"] == 1


def test_spool_poison_job_quarantined_after_retry_budget(tmp_path):
    """Kill-loop: a poison job (every worker that claims it dies without
    heartbeating) is reclaimed at most ``retry_budget`` times, then
    quarantined to failed/ — never lease-reclaimed forever."""
    spool = Spool(str(tmp_path / "sp"), lease_s=60.0, retry_budget=2,
                  backoff_base_s=0.0)
    spool.submit("poison", {"x": 1})
    cycles = 0
    while cycles < 10:                             # kill loop
        job = spool.claim(f"doomed-{cycles}")
        if job is None:
            break
        assert job.key == "poison" and job.attempts == cycles
        old = time.time() - 120.0                  # worker dies silently
        os.utime(job.active_path, (old, old))
        assert spool.reclaim() == 1
        cycles += 1
    # initial claim + retry_budget requeues, then quarantine
    assert cycles == spool.retry_budget + 1
    assert spool.counts() == {"jobs": 0, "active": 0, "done": 0,
                              "failed": 1}
    fail = spool.failure("poison")
    assert "retry budget exhausted" in fail["error"]
    assert fail["attempts"] == spool.retry_budget + 1
    # an operator resubmit gives the job a fresh budget
    assert spool.submit("poison", {"x": 1})
    job = spool.claim("w-new")
    assert job is not None and job.attempts == 0


def test_spool_healthy_slow_job_survives_the_budget(tmp_path):
    """The budget counts dead-worker reclaims, not wall time: a job
    whose worker heartbeats is never charged an attempt. (Lease is 20x
    the heartbeat interval so a loaded CI machine can't fake a death.)"""
    spool = Spool(str(tmp_path / "sp"), lease_s=2.0, retry_budget=1)
    spool.submit("slow", {"x": 1})
    job = spool.claim("w0")
    for _ in range(4):
        time.sleep(0.1)
        assert job.heartbeat()
        assert spool.reclaim() == 0                # lease always fresh
    spool.complete(job, {"ok": True}, wall_s=0.4)
    assert spool.result("slow")["record"] == {"ok": True}
    assert spool.counts()["failed"] == 0


# -- worker loop -----------------------------------------------------------

def test_run_worker_drains_and_publishes(tmp_path):
    root = str(tmp_path / "sp")
    spool = Spool(root)
    for i in range(5):
        spool.submit(f"j{i}", {"i": i})
    n = run_worker(root, worker="w0", hb_s=0.05,
                   refine_fn=lambda p: {"out": p["i"] * 2})
    assert n == 5
    counts = spool.counts()
    assert counts["done"] == 5 and counts["jobs"] == 0
    assert counts["active"] == 0
    for i in range(5):
        res = spool.result(f"j{i}")
        assert res["record"] == {"out": i * 2}
        assert res["worker"] == "w0"
        assert res["wall_s"] >= 0


def test_run_worker_records_failures(tmp_path):
    root = str(tmp_path / "sp")
    spool = Spool(root)
    spool.submit("ok", {"i": 1})
    spool.submit("boom", {"i": -1})

    def refine(p):
        if p["i"] < 0:
            raise ValueError("negative")
        return {"ok": True}

    n = run_worker(root, worker="w0", refine_fn=refine)
    assert n == 1
    assert spool.result("ok")["record"] == {"ok": True}
    assert "negative" in spool.failure("boom")["error"]


def test_run_worker_heartbeat_keeps_lease(tmp_path):
    """A slow job heartbeats fast enough that an aggressive janitor
    never reclaims it."""
    root = str(tmp_path / "sp")
    spool = Spool(root, lease_s=0.3)
    spool.submit("slow", {"i": 0})
    reclaims = []

    def slow_refine(p):
        for _ in range(4):
            time.sleep(0.15)
            reclaims.append(spool.reclaim(lease_s=0.3))
        return {"done": True}

    run_worker(root, worker="w0", hb_s=0.05, refine_fn=slow_refine)
    assert sum(reclaims) == 0
    assert spool.result("slow")["record"] == {"done": True}


# -- journal ---------------------------------------------------------------

def test_journal_roundtrip_and_all_done(tmp_path):
    p = str(tmp_path / "j.jsonl")
    j = CampaignJournal(p)
    j.start(campaign="c", backend="spool", grid_points=4, to_refine=3)
    j.point("k1", "cached", point_id="p1")
    j.point("k2", "done", worker="w0", wall_s=0.5)
    j.point("k3", "failed", worker="w1", error="boom")
    j.end({"refined": 3, "cache_hits": 1, "simulated": 2})
    view = CampaignJournal.load(p)
    c = view.counts()
    assert c == {"done": 1, "cached": 1, "failed": 1, "other": 0,
                 "total": 3}
    assert view.cache_hits() == 1 and view.simulated() == 1
    assert not view.all_done()                     # one failed
    assert view.summary["cache_hits"] == 1
    # torn tail line (killed writer) is tolerated
    with open(p, "a") as f:
        f.write('{"ev": "point", "key": "k4"')
    assert CampaignJournal.load(p).counts()["total"] == 3


def test_journal_all_done_happy_path(tmp_path):
    p = str(tmp_path / "j.jsonl")
    j = CampaignJournal(p)
    j.start(campaign="c", backend="inline", grid_points=2, to_refine=2)
    j.point("k1", "done", worker="inline", wall_s=0.1)
    j.point("k2", "cached")
    j.end({"refined": 2})
    assert CampaignJournal.load(p).all_done()
    assert not CampaignJournal.load(p).all_done(min_points=3)


# -- backend factory -------------------------------------------------------

def test_get_backend():
    assert isinstance(get_backend("inline"), InlineBackend)
    assert get_backend("pool", workers=2).name == "pool"
    bk = get_backend("spool", workers=0, spool_dir="/tmp/x")
    assert bk.name == "spool" and bk.workers == 0
    with pytest.raises(ValueError):
        get_backend("spool")                       # needs spool_dir
    with pytest.raises(ValueError):
        get_backend("carrier-pigeon")


# -- campaign-level behavior ----------------------------------------------

def _drain_in_thread(root, refine_fn=None):
    """Background in-process spool worker; runs until told to stop."""
    from repro.sweep.refine import refine_point

    fn = refine_fn or refine_point
    stop = threading.Event()

    def loop():
        while not stop.is_set():
            n = run_worker(root, worker="thread-w", hb_s=0.2, refine_fn=fn)
            if n == 0:
                time.sleep(0.05)

    t = threading.Thread(target=loop, daemon=True)
    t.start()
    return t, stop


def test_campaign_spool_backend_matches_inline(tmp_path):
    """Acceptance: inline and spool backends produce identical campaign
    records (spool drained by an in-process worker; no subprocesses)."""
    spec = _small_spec()
    inline = run_campaign(spec, workers=0, use_cache=False)
    root = str(tmp_path / "spool")
    t, stop = _drain_in_thread(root)
    bk = SpoolBackend(root, workers=0, poll_s=0.05, timeout_s=120)
    spooled = run_campaign(spec, backend=bk, use_cache=False,
                           journal_path=str(tmp_path / "j.jsonl"))
    stop.set()
    t.join(timeout=10)
    assert spooled.records == inline.records
    assert json.dumps(spooled.records) == json.dumps(inline.records)
    assert spooled.summary["backend"] == "spool"
    view = CampaignJournal.load(str(tmp_path / "j.jsonl"))
    assert view.all_done()
    assert {e["worker"] for e in view.points.values()} == {"thread-w"}


def test_campaign_spool_resume_skips_done_jobs(tmp_path):
    """Kill-and-resume at the spool level: results that survived a dead
    runner are collected without re-simulation."""
    # pin batch=0: this test counts spool jobs (one per point), so it
    # must not merge points into batch jobs under REPRO_REFINE_BATCH
    spec = _small_spec(refine=RefineSpec(mode="all", batch=0))
    root = str(tmp_path / "spool")
    jpath = str(tmp_path / "j.jsonl")

    # first (interrupted) run: drain the spool, then throw away the
    # runner's result — exactly what a SIGKILLed runner leaves behind
    t, stop = _drain_in_thread(root)
    run_campaign(spec, backend=SpoolBackend(root, workers=0, poll_s=0.05,
                                            timeout_s=120),
                 use_cache=False)
    stop.set()
    t.join(timeout=10)
    assert Spool(root).counts()["done"] == 2

    # resume: only a tripwire worker attached — the surviving done
    # files must be the sole source of records
    calls = []
    t2, stop2 = _drain_in_thread(root,
                                 refine_fn=lambda p: calls.append(p) or {})
    res = run_campaign(spec, backend=SpoolBackend(root, workers=0,
                                                  poll_s=0.05,
                                                  timeout_s=60),
                       use_cache=False, journal_path=jpath)
    stop2.set()
    t2.join(timeout=10)
    assert calls == []                             # zero re-simulation
    assert len(res.refined) == 2
    assert CampaignJournal.load(jpath).all_done()


def test_campaign_resume_via_cache_counters(tmp_path):
    """Acceptance: a re-invoked campaign completes with zero
    re-simulation, verified via the cache-hit counters in the journal."""
    spec = _small_spec(cache_dir=str(tmp_path / "cache"))
    j1, j2 = str(tmp_path / "j1.jsonl"), str(tmp_path / "j2.jsonl")
    run_campaign(spec, workers=0, journal_path=j1)
    res = run_campaign(spec, workers=0, journal_path=j2)
    v1, v2 = CampaignJournal.load(j1), CampaignJournal.load(j2)
    assert v1.summary["simulated"] == 2 and v1.summary["cache_hits"] == 0
    assert v2.summary["simulated"] == 0 and v2.summary["cache_hits"] == 2
    assert v2.all_done() and v2.counts()["cached"] == 2
    assert all(r["cached"] for r in res.refined)


def test_backends_write_through_to_cache(tmp_path):
    """Each record lands in the result cache as soon as it is refined —
    a runner killed mid-batch loses nothing already simulated."""
    from repro.sweep.cache import ResultCache

    class SpyCache(ResultCache):
        def __init__(self, root):
            super().__init__(root)
            self.put_order = []

        def put(self, key, record):
            self.put_order.append(key)
            return super().put(key, record)

    root = str(tmp_path / "sp")
    cache = SpyCache(str(tmp_path / "cache"))
    t, stop = _drain_in_thread(root, refine_fn=lambda p: {"v": p["i"]})
    bk = SpoolBackend(root, workers=0, poll_s=0.05, timeout_s=60)
    recs = bk.refine([{"i": 1}, {"i": 2}], keys=["ka", "kb"], cache=cache)
    stop.set()
    t.join(timeout=10)
    assert recs == [{"v": 1}, {"v": 2}]
    assert sorted(cache.put_order) == ["ka", "kb"]
    assert cache.get("ka") == {"v": 1}             # durable on disk


def test_spool_backend_surfaces_failures(tmp_path):
    root = str(tmp_path / "sp")
    spool = Spool(root)
    bk = SpoolBackend(root, workers=0, poll_s=0.05, timeout_s=60)

    def explode(p):
        raise ValueError("no")

    t, stop = _drain_in_thread(root, refine_fn=explode)
    with pytest.raises(BackendError, match="failed"):
        bk.refine([{"p": 1}], keys=["kf"])
    stop.set()
    t.join(timeout=10)
    assert spool.failure("kf") is not None


# -- subprocess integration (slow lane) ------------------------------------

@pytest.mark.slow
def test_worker_cli_end_to_end(tmp_path):
    """`python -m repro.exec worker` drains a spool populated by a
    spool-backend campaign with workers=0, plus status/journal CLIs."""
    spec = _small_spec(name="cli_exec")
    root = str(tmp_path / "spool")
    jpath = str(tmp_path / "j.jsonl")

    done = {}

    def run():
        done["res"] = run_campaign(
            spec, backend=SpoolBackend(root, workers=0, poll_s=0.1,
                                       timeout_s=240),
            use_cache=False, journal_path=jpath)

    t = threading.Thread(target=run, daemon=True)
    t.start()
    deadline = time.time() + 120
    while time.time() < deadline and not os.path.isdir(
            os.path.join(root, "jobs")):
        time.sleep(0.1)
    r = subprocess.run(
        [sys.executable, "-m", "repro.exec", "worker", root],
        capture_output=True, text=True, timeout=240, env=_env())
    assert r.returncode == 0, r.stderr
    t.join(timeout=120)
    assert not t.is_alive()
    assert len(done["res"].refined) == 2

    r2 = subprocess.run(
        [sys.executable, "-m", "repro.exec", "status", root],
        capture_output=True, text=True, timeout=60, env=_env())
    assert r2.returncode == 0 and "done,2" in r2.stdout
    r3 = subprocess.run(
        [sys.executable, "-m", "repro.exec", "journal", jpath,
         "--expect-done"],
        capture_output=True, text=True, timeout=60, env=_env())
    assert r3.returncode == 0, r3.stdout
    assert "all_done,True" in r3.stdout


@pytest.mark.slow
def test_campaign_spool_subprocess_workers_match_inline(tmp_path):
    """Full stack: run_campaign(backend='spool', workers=2) spawns real
    worker subprocesses and matches the inline records byte-for-byte."""
    spec = _small_spec(name="sub_exec")
    inline = run_campaign(spec, workers=0, use_cache=False)
    sp = run_campaign(spec, backend="spool", workers=2, use_cache=False,
                      spool_dir=str(tmp_path / "spool"),
                      journal_path=str(tmp_path / "j.jsonl"))
    assert json.dumps(sp.records) == json.dumps(inline.records)
    view = CampaignJournal.load(str(tmp_path / "j.jsonl"))
    assert view.all_done()
