"""Deterministic fallback for ``hypothesis`` when it is not installed.

The test suite's property tests use a small subset of the hypothesis API
(``given``/``settings`` and the ``lists``/``floats``/``integers``/``tuples``
strategies). Environments built from ``pyproject.toml``'s ``[test]`` extra
get the real library; hermetic containers without it fall back to this
seeded-random implementation so the suite still collects and the
properties are still exercised on boundary + pseudo-random examples.

Installed into ``sys.modules`` by ``conftest.py`` *only* when the real
package is absent — it never shadows a real install.
"""
from __future__ import annotations

import random
import struct
import sys
import types
import zlib
from typing import Any, List

_MAX_FALLBACK_EXAMPLES = 25


class _Strategy:
    """A strategy draws one example from a seeded ``random.Random``."""

    def __init__(self, draw_fn, boundary=()):
        self._draw = draw_fn
        self.boundary = tuple(boundary)  # deterministic edge-case examples

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)


def _f32(x: float) -> float:
    return struct.unpack("f", struct.pack("f", x))[0]


def floats(min_value=None, max_value=None, *, allow_nan=True,
           allow_infinity=None, width=64, **_kw) -> _Strategy:
    lo = 0.0 if min_value is None else float(min_value)
    hi = 1.0 if max_value is None else float(max_value)
    cast = _f32 if width == 32 else float

    def draw(rng: random.Random) -> float:
        r = rng.random()
        # bias towards the edges the way hypothesis shrinking would explore
        if r < 0.1:
            v = lo
        elif r < 0.2:
            v = hi
        else:
            v = lo + rng.random() * (hi - lo)
        return cast(v)

    mid = lo + 0.5 * (hi - lo)
    return _Strategy(draw, boundary=(cast(lo), cast(hi), cast(mid)))


def integers(min_value=None, max_value=None) -> _Strategy:
    lo = -(2 ** 31) if min_value is None else int(min_value)
    hi = 2 ** 31 if max_value is None else int(max_value)
    return _Strategy(lambda rng: rng.randint(lo, hi), boundary=(lo, hi))


def lists(elements: _Strategy, *, min_size=0, max_size=None,
          unique=False, **_kw) -> _Strategy:
    cap = (min_size + 10) if max_size is None else max_size

    def draw(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, cap)
        out = [elements.example(rng) for _ in range(n)]
        if unique:
            seen, uniq = set(), []
            for v in out:
                if v not in seen:
                    seen.add(v)
                    uniq.append(v)
            out = uniq + [elements.example(rng)
                          for _ in range(min_size - len(uniq))]
        return out

    bnd = []
    if min_size == 0:
        bnd.append([])
    if elements.boundary:
        bnd.append([elements.boundary[0]] * max(min_size, 1))
    return _Strategy(draw, boundary=bnd)


def tuples(*elems: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))


def sampled_from(choices) -> _Strategy:
    seq = list(choices)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))],
                     boundary=seq[:1])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5, boundary=(False, True))


def just(value) -> _Strategy:
    return _Strategy(lambda rng: value, boundary=(value,))


def settings(max_examples: int = 100, deadline=None, **_kw):
    """Decorator recording the example budget on the test function."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strategies: _Strategy, **kw_strategies: _Strategy):
    if kw_strategies:
        raise NotImplementedError(
            "hypothesis fallback stub supports positional strategies only")

    def deco(fn):
        def wrapper(*args, **kwargs):
            budget = min(getattr(fn, "_stub_max_examples", 100),
                         _MAX_FALLBACK_EXAMPLES)
            # stable per-test seed: same examples on every run/machine
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            cases = []
            if all(s.boundary for s in strategies):
                cases.extend(zip(*(s.boundary for s in strategies)))
            while len(cases) < budget:
                cases.append(tuple(s.example(rng) for s in strategies))
            for case in cases[:budget]:
                try:
                    fn(*args, *case, **kwargs)
                except _Unsatisfied:
                    continue  # assume() discarded this example
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"falsifying example (hypothesis fallback): "
                        f"{fn.__name__}{case!r}") from e

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # NOTE: deliberately no __wrapped__ — pytest would follow it and
        # treat the property arguments as fixtures.
        wrapper._stub_inner = fn
        return wrapper

    return deco


def assume(condition: bool) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


def example(*_a, **_k):
    def deco(fn):
        return fn

    return deco


def install() -> None:
    """Register this stub as ``hypothesis`` + ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.example = example
    mod.HealthCheck = HealthCheck
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("floats", "integers", "lists", "tuples", "sampled_from",
                 "booleans", "just"):
        setattr(st_mod, name, globals()[name])
    mod.strategies = st_mod
    mod.__is_repro_stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
