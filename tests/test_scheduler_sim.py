"""Scheduler + barriers + full-system simulation behavior (paper §3.3)."""

from repro.core import Environment
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.tasks import BarrierScoreboard, Task
from repro.graph.workloads import mobilenet_v2, resnet50, tiny_yolo_v2
from repro.hw.chip import System, simulate
from repro.hw.dma import DmaDescriptor
from repro.hw.mxu import GemmSpec
from repro.hw.presets import V5E, paper_skew


def test_barrier_scoreboard_semantics():
    env = Environment()
    sb = BarrierScoreboard(env)
    log = []

    def consumer():
        yield sb.wait(7, need=2)
        log.append(env.now)

    def producer():
        yield env.timeout(5)
        sb.signal(7)
        yield env.timeout(5)
        sb.signal(7)

    env.process(consumer())
    env.process(producer())
    env.run()
    assert log == [10]          # released only at count=2
    # late waiter passes immediately
    done = []

    def late():
        yield sb.wait(7, need=1)
        done.append(env.now)

    env.process(late())
    env.run()
    assert done == [10]


def test_dependency_enforced():
    """Consumer GEMM must not start before producer DMA signals."""
    tasks = [
        Task("dma", DmaDescriptor(nbytes=4 * 2**20), signals=(1,),
             name="w"),
        Task("tile0.mxu", GemmSpec(m=512, n=512, k=512), waits=((1, 1),),
             name="mm"),
    ]
    sysm = System(V5E, n_tiles=1)
    sysm.run_workload(tasks)
    recs = {r.task: r for r in sysm.tracer.tasks}
    assert recs["mm"].t_start >= recs["w"].t_end


def test_independent_tasks_overlap():
    """No barriers -> MXU and DMA run concurrently (event concurrency)."""
    tasks = [
        Task("dma", DmaDescriptor(nbytes=64 * 2**20), name="d"),
        Task("tile0.mxu", GemmSpec(m=2048, n=2048, k=2048), name="m"),
    ]
    sysm = System(V5E, n_tiles=1)
    rep = sysm.run_workload(tasks)
    recs = {r.task: r for r in sysm.tracer.tasks}
    overlap = min(recs["d"].t_end, recs["m"].t_end) - max(
        recs["d"].t_start, recs["m"].t_start)
    assert overlap > 0


def test_sim_determinism():
    ops = mobilenet_v2()
    cfg = paper_skew()

    def once():
        cw = compile_ops(ops, cfg, CompileOptions(n_tiles=2))
        sysm = System(cfg, n_tiles=2)
        rep = sysm.run_workload(cw.tasks)
        return rep.makespan_ns

    assert once() == once()


def test_tile_scaling_speedup():
    """Fig 5: 1 -> 2 tiles speeds up meaningfully."""
    ops = resnet50()
    cfg = paper_skew()
    t = {}
    for nt in (1, 2):
        cw = compile_ops(ops, cfg, CompileOptions(n_tiles=nt))
        t[nt] = simulate(cw.tasks, cfg, n_tiles=nt).makespan_ns
    assert t[1] / t[2] > 1.4


def test_mac_scaling_sublinear():
    """Fig 5: 2K -> 4K MACs alone gives clearly sub-2x improvement."""
    ops = resnet50()
    t = {}
    for mx in (1, 2):
        cfg = paper_skew(n_mxu=mx)
        cw = compile_ops(ops, cfg, CompileOptions(n_tiles=1))
        t[mx] = simulate(cw.tasks, cfg, n_tiles=1).makespan_ns
    speedup = t[1] / t[2]
    assert 1.05 < speedup < 1.9


def test_membw_scaling_matters():
    """Fig 7: DDR/HBM BW scaling has significant impact at NPU scale."""
    ops = tiny_yolo_v2()
    t = {}
    for bw in (8.0, 64.0):
        cfg = paper_skew(hbm_gbps=bw)
        cw = compile_ops(ops, cfg, CompileOptions(n_tiles=2))
        t[bw] = simulate(cw.tasks, cfg, n_tiles=2).makespan_ns
    assert t[8.0] / t[64.0] > 1.3


def test_compression_helps_bw_bound():
    ops = tiny_yolo_v2()
    cfg = paper_skew(hbm_gbps=8.0, dma_compression=True)
    base = compile_ops(ops, cfg, CompileOptions(n_tiles=1))
    comp = compile_ops(ops, cfg, CompileOptions(n_tiles=1, compression=True))
    t0 = simulate(base.tasks, cfg, n_tiles=1).makespan_ns
    t1 = simulate(comp.tasks, cfg, n_tiles=1).makespan_ns
    assert t1 < t0


def test_sparsity_reduces_compute():
    ops = resnet50()
    cfg = paper_skew()
    base = compile_ops(ops, cfg, CompileOptions(n_tiles=1))
    sparse = compile_ops(ops, cfg, CompileOptions(n_tiles=1, sparsity=True))
    assert sparse.total_flops < base.total_flops
    t0 = simulate(base.tasks, cfg, n_tiles=1).makespan_ns
    t1 = simulate(sparse.tasks, cfg, n_tiles=1).makespan_ns
    assert t1 < t0


def test_simulation_speed_objective():
    """Paper §2.3: full-model simulation within minutes — we require
    seconds for ResNet50-224."""
    import time

    ops = resnet50()
    cfg = paper_skew()
    cw = compile_ops(ops, cfg, CompileOptions(n_tiles=2))
    t0 = time.time()
    simulate(cw.tasks, cfg, n_tiles=2)
    assert time.time() - t0 < 30.0
