#!/usr/bin/env python
"""Staleness check for the captured-HLO workload fixtures (CI gate).

Stdlib-only — runs without jax or even the repro package installed.
Verifies, for ``src/repro/configs/hlo/``:

* ``manifest.json`` exists, names its generator, and every fixture entry
  carries the required keys (file, sha256, twin, layers, phase, band);
* every referenced ``.hlo.txt.gz`` exists and its *decompressed* text
  hashes to the recorded SHA-256 (the fixture-vs-manifest staleness
  contract: regenerating a capture without ``tools/gen_hlo_fixtures.py``
  fails here);
* no orphan ``.hlo.txt.gz`` files sit next to the manifest unlisted;
* bands are sane ([lo, hi] with 0 < lo <= hi).

Exit 0 clean, 1 with one line per problem.
"""
import gzip
import hashlib
import json
import os

REQUIRED_KEYS = ("file", "sha256", "twin", "layers", "phase", "band")


def check(fixture_dir: str) -> int:
    problems = []
    man_path = os.path.join(fixture_dir, "manifest.json")
    if not os.path.exists(man_path):
        print(f"PROBLEM: {man_path} missing")
        return 1
    with open(man_path) as f:
        man = json.load(f)
    if man.get("generator") != "tools/gen_hlo_fixtures.py":
        problems.append(f"{man_path}: generator field missing/wrong")
    fixtures = man.get("fixtures", {})
    if not fixtures:
        problems.append(f"{man_path}: no fixtures")
    listed = set()
    for name, meta in sorted(fixtures.items()):
        missing = [k for k in REQUIRED_KEYS if k not in meta]
        if missing:
            problems.append(f"{name}: manifest entry missing {missing}")
            continue
        listed.add(meta["file"])
        band = meta["band"]
        if (not isinstance(band, list) or len(band) != 2
                or not 0 < band[0] <= band[1]):
            problems.append(f"{name}: malformed band {band!r}")
        path = os.path.join(fixture_dir, meta["file"])
        if not os.path.exists(path):
            problems.append(f"{name}: {meta['file']} missing")
            continue
        with gzip.open(path, "rb") as gz:
            digest = hashlib.sha256(gz.read()).hexdigest()
        if digest != meta["sha256"]:
            problems.append(
                f"{name}: {meta['file']} is stale — decompressed text "
                f"hashes to {digest[:12]}..., manifest says "
                f"{meta['sha256'][:12]}...; rerun tools/gen_hlo_fixtures.py")
    for fn in sorted(os.listdir(fixture_dir)):
        if fn.endswith(".hlo.txt.gz") and fn not in listed:
            problems.append(f"orphan fixture {fn}: not in manifest.json")
    for p in problems:
        print(f"PROBLEM: {p}")
    if not problems:
        print(f"{len(fixtures)} HLO fixtures fresh "
              f"(hashes match manifest.json)")
    return 1 if problems else 0


def main() -> int:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return check(os.path.join(repo, "src", "repro", "configs", "hlo"))


if __name__ == "__main__":
    raise SystemExit(main())
