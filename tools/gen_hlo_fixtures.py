#!/usr/bin/env python
"""Regenerate the captured-HLO workload fixtures under
``src/repro/configs/hlo/``.

Each fixture is the scheduled HLO text of one real jax program —
``launch.programs.build_program(arch, shape, mesh).lower().compile()
.as_text()`` — gzipped next to a ``manifest.json`` entry recording the
generation parameters, the hand-built twin workload name, the documented
hand-built-vs-ingested analytic deviation band, and the SHA-256 of the
decompressed text. ``tools/check_fixtures.py`` (stdlib-only, runs in CI)
verifies hashes and manifest shape without importing jax; this script is
the only thing that may rewrite the captures.

Needs jax (CPU is fine — compiles take ~1s each); run from the repo
root:

    python tools/gen_hlo_fixtures.py [--out src/repro/configs/hlo]

Bands are *preserved* from an existing manifest on regeneration (they
are measured, documented numbers — see docs/CAMPAIGNS.md); a brand-new
fixture starts with the permissive default and must be tightened after
running ``python -m repro.sweep crosscheck-hlo``.
"""
import argparse
import gzip
import hashlib
import json
import os
import sys

# host-platform device count must be pinned before jax is imported, or
# the tp2 capture cannot build its 1x2 mesh on a CPU host
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

DEFAULT_BAND = [0.2, 5.0]

# (fixture, arch, seq/kv, batch, kind, mesh, twin)
CAPTURES = [
    ("qwen2_1_5b_prefill", "qwen2-1.5b", 128, 1, "prefill", (1, 1),
     "lm/qwen2-1.5b/L28/s128b1tp1"),
    ("qwen2_1_5b_decode", "qwen2-1.5b", 256, 4, "decode", (1, 1),
     "lm/qwen2-1.5b/L28/decode/kv256b4tp1"),
    ("qwen2_1_5b_prefill_tp2", "qwen2-1.5b", 128, 1, "prefill", (1, 2),
     "lm/qwen2-1.5b/L28/s128b1tp2"),
]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=os.path.join(
        REPO, "src", "repro", "configs", "hlo"))
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_mesh
    from repro.launch.programs import build_program

    os.makedirs(args.out, exist_ok=True)
    man_path = os.path.join(args.out, "manifest.json")
    old: dict = {"fixtures": {}}
    if os.path.exists(man_path):
        with open(man_path) as f:
            old = json.load(f)

    fixtures = {}
    for name, arch, seq, batch, kind, mesh_shape, twin in CAPTURES:
        cfg = get_config(arch)
        shape = ShapeSpec(f"fx_{name}", seq, batch, kind)
        mesh = make_mesh(mesh_shape, ("data", "model"))
        text = build_program(cfg, shape, mesh).lower().compile().as_text()
        fname = f"{name}.hlo.txt.gz"
        # mtime=0 + fixed filename inside the archive keep regeneration
        # byte-deterministic for identical HLO text
        with open(os.path.join(args.out, fname), "wb") as raw:
            with gzip.GzipFile(fileobj=raw, mode="wb", filename="",
                               mtime=0) as gz:
                gz.write(text.encode())
        prev = old.get("fixtures", {}).get(name, {})
        fixtures[name] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "arch": arch,
            "shape": {"seq_len": seq, "global_batch": batch, "kind": kind},
            "mesh": list(mesh_shape),
            "layers": cfg.n_layers,
            "phase": kind,
            "pod_size": 0,
            "twin": twin,
            "band": prev.get("band", list(DEFAULT_BAND)),
        }
        print(f"{name}: {len(text) / 1024:.0f} KB text -> {fname}")

    with open(man_path, "w") as f:
        json.dump({"generator": "tools/gen_hlo_fixtures.py",
                   "fixtures": fixtures}, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {man_path} ({len(fixtures)} fixtures)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
