#!/usr/bin/env python3
"""Docs integrity check (run by CI; stdlib only).

1. Every intra-repo markdown link in ``README.md`` and ``docs/*.md``
   must resolve to an existing file (anchors and external URLs are not
   checked).
2. Every package under ``src/repro/`` must be mentioned in
   ``docs/ARCHITECTURE.md`` (as ``src/repro/<pkg>`` or ``repro.<pkg>``)
   so the architecture tour cannot silently go stale.

Exit code 0 when clean; 1 with one line per problem otherwise.
"""
from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — excluding images; target split from an optional title
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_EXTERNAL = ("http://", "https://", "mailto:", "ftp:")


def _md_files():
    files = [os.path.join(REPO, "README.md")]
    files += sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def check_links() -> list:
    problems = []
    for path in _md_files():
        rel = os.path.relpath(path, REPO)
        text = open(path, encoding="utf-8").read()
        # strip fenced code blocks: JSON/bash snippets are not links
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for m in _LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(resolved):
                problems.append(f"{rel}: broken link -> {m.group(1)}")
    return problems


def check_architecture_mentions() -> list:
    arch_md = os.path.join(REPO, "docs", "ARCHITECTURE.md")
    if not os.path.exists(arch_md):
        return ["docs/ARCHITECTURE.md is missing"]
    text = open(arch_md, encoding="utf-8").read()
    problems = []
    pkg_root = os.path.join(REPO, "src", "repro")
    for entry in sorted(os.listdir(pkg_root)):
        full = os.path.join(pkg_root, entry)
        if not os.path.isdir(full) or entry.startswith("__"):
            continue
        if f"src/repro/{entry}" not in text and f"repro.{entry}" not in text:
            problems.append(
                f"docs/ARCHITECTURE.md: package src/repro/{entry} "
                f"is not mentioned")
    return problems


def main() -> int:
    problems = check_links() + check_architecture_mentions()
    for p in problems:
        print(p, file=sys.stderr)
    if problems:
        print(f"docs check FAILED: {len(problems)} problem(s)",
              file=sys.stderr)
        return 1
    n_files = len(_md_files())
    print(f"docs check OK ({n_files} markdown files, all intra-repo links "
          f"resolve, every src/repro package covered)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
