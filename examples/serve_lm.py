"""Serving example: batched requests through the ServeEngine, including a
straggler that exceeds its decode deadline and gets re-queued.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import REGISTRY
from repro.models import build_model
from repro.serve import ServeEngine

cfg = REGISTRY["qwen2-1.5b"].reduced()
model = build_model(cfg, remat=False)
params = model.init(jax.random.PRNGKey(0))
eng = ServeEngine(model, params, smax=96)

rng = np.random.default_rng(7)
normal = [eng.submit(rng.integers(0, cfg.vocab_size, 10), max_new=12)
          for _ in range(5)]
straggler = eng.submit(rng.integers(0, cfg.vocab_size, 10), max_new=64,
                       deadline_steps=8)

t0 = time.time()
out = eng.run(batch_size=3)
dt = time.time() - t0
tok = sum(len(v) for v in out.values())
print(f"{len(out)} completed, {len(eng.evicted)} evicted after retries, "
      f"{tok} tokens in {dt:.2f}s")
for rid in normal:
    print(f"  req {rid}: {out[rid][:8]}...")
print(f"  straggler {straggler}: "
      f"{'completed' if straggler in out else 'evicted (deadline)'}")
