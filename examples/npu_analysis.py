"""The paper's analysis workflow end-to-end (Figs 5-9 in one script):
computation scaling, frequency scaling, memory-BW scaling, power profile
and a DVFS policy pick — all on the event-simulated NPU.

  PYTHONPATH=src python examples/npu_analysis.py
"""
import numpy as np

from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import resnet50
from repro.hw.chip import System, simulate
from repro.hw.presets import paper_skew
from repro.power.dvfs import choose_operating_point, sweep
from repro.power.powerem import PowerEM

ops = resnet50()

print("== computation scaling (Fig 5) ==")
base = None
for n_mxu, tag in ((1, "2K MACs"), (2, "4K MACs")):
    for nt in (1, 2, 4):
        cfg = paper_skew(n_mxu=n_mxu)
        cw = compile_ops(ops, cfg, CompileOptions(n_tiles=nt))
        t = simulate(cw.tasks, cfg, n_tiles=nt).makespan_ns
        fps = 1e9 / t
        base = base or fps
        print(f"  {tag} x {nt} tile(s): {fps:7.1f} inf/s "
              f"({fps/base:.2f}x)")

print("== memory-BW scaling (Fig 7) ==")
for bw in (8, 17, 34, 68):
    cfg = paper_skew(hbm_gbps=float(bw))
    cw = compile_ops(ops, cfg, CompileOptions(n_tiles=2))
    t = simulate(cw.tasks, cfg, n_tiles=2).makespan_ns
    print(f"  DDR {bw:3d} GB/s: {1e9/t:7.1f} inf/s")

print("== frequency scaling + power (Figs 6/9) ==")
cfg = paper_skew()
pts = sweep(lambda c: compile_ops(ops, c, CompileOptions(n_tiles=2)).tasks,
            cfg, [0.4, 0.6, 0.8, 1.0, 1.2], n_tiles=2)
for p in pts:
    print(f"  {p.freq_ghz:.1f} GHz @ {p.volt:.3f} V: {p.inf_per_s:7.1f} "
          f"inf/s, {p.avg_w:6.2f} W avg, {p.inf_per_j:6.1f} inf/J")
pick = choose_operating_point(pts, min_inf_per_s=0.6 * pts[-1].inf_per_s)
print(f"  DVFS pick for 60% of peak perf: {pick.freq_ghz} GHz "
      f"({pick.avg_w:.2f} W)")

print("== power profile (Fig 8) ==")
cfg = paper_skew()
cw = compile_ops(ops, cfg, CompileOptions(n_tiles=2))
sysm = System(cfg, n_tiles=2)
sysm.run_workload(cw.tasks)
rep = PowerEM(cfg, n_tiles=2).analyze(sysm.tracer, pti_ns=50_000)
mods = [m for m in rep.series if not m.startswith("tile1")]
print("  PTI " + " ".join(f"{m:>10s}" for m in mods))
for b in range(min(6, len(rep.total_series))):
    print(f"  {b:3d} " + " ".join(f"{rep.series[m][b]:10.2f}" for m in mods))
print(f"  avg {rep.avg_w:.2f} W  peak {rep.peak_w:.2f} W")
