"""End-to-end driver: train the full (non-reduced) SmolLM-135M for a few
hundred steps on synthetic data, with periodic checkpoints and a
kill-and-resume demonstration.

Full run (~135M params; takes a while on 1 CPU):
  PYTHONPATH=src python examples/train_lm.py --steps 300

Smoke run:
  PYTHONPATH=src python examples/train_lm.py --steps 8 --seq 64 --batch 2
"""
import argparse
import os
import shutil

from repro.launch.train import train


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    p.add_argument("--fresh", action="store_true")
    args = p.parse_args()
    if args.fresh and os.path.isdir(args.ckpt_dir):
        shutil.rmtree(args.ckpt_dir)

    state, losses = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        reduced=False, ckpt_dir=args.ckpt_dir,
        save_every=max(args.steps // 6, 1), log_every=max(args.steps // 30, 1))
    k = max(len(losses) // 10, 1)
    first, last = sum(losses[:k]) / k, sum(losses[-k:]) / k
    print(f"\nloss: first-{k}-avg {first:.4f} -> last-{k}-avg {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    print(f"checkpoints in {args.ckpt_dir} — rerun the same command to "
          f"resume from the latest one (fault-tolerance path).")


if __name__ == "__main__":
    main()
