"""Quickstart: the two halves of this repo in ~60 seconds on CPU.

  (A) the workload framework — build an assigned architecture, train a few
      steps, prefill + decode;
  (B) TPU-EM — compile a CNN workload to an event-simulated NPU, get
      timing + power, and replay a step through the vectorized sweeper.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, SHAPES
from repro.core.vectorized import from_tasks, params_of, schedule_many
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import mobilenet_v2
from repro.hw.chip import System
from repro.hw.presets import paper_skew
from repro.models import build_model
from repro.power.powerem import PowerEM
from repro.train import SyntheticData, init_state, make_train_step

print("=== (A) workload framework ===")
cfg = REGISTRY["smollm-135m"].reduced()
model = build_model(cfg)
state = init_state(model, jax.random.PRNGKey(0), dtype=jnp.float32)
data = SyntheticData(cfg, SHAPES["train_4k"], batch_override=4,
                     seq_override=64)
step = jax.jit(make_train_step(model, None), donate_argnums=(0,))
for s in range(5):
    state, m = step(state, data.batch_at(s))
    print(f"  train step {s}: loss {float(m['loss']):.4f}")

prompt = {"tokens": jnp.asarray(
    np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 12), np.int32))}
logits, cache = model.prefill(state["params"], prompt, smax=64)
tok = jnp.argmax(logits, -1)[:, None]
out = []
for _ in range(8):
    logits, cache = model.decode_step(state["params"], cache, tok)
    tok = jnp.argmax(logits, -1)[:, None]
    out.append(int(tok[0, 0]))
print(f"  greedy decode: {out}")

print("\n=== (B) TPU-EM: event-simulated NPU ===")
hw = paper_skew()                       # 2K-MAC NPU-scale config
ops = mobilenet_v2()
cw = compile_ops(ops, hw, CompileOptions(n_tiles=2))
sysm = System(hw, n_tiles=2)
rep = sysm.run_workload(cw.tasks)
print(f"  MobileNetV2 on 2 tiles: {rep.makespan_ns/1e6:.3f} ms "
      f"({1e9/rep.makespan_ns:.0f} inf/s), {len(cw.tasks)} tasks")
for mod in ("tile0.mxu", "tile0.vpu", "dma", "hbm"):
    print(f"    {mod:10s} utilization {rep.utilization(mod)*100:5.1f}%")

pem = PowerEM(hw, n_tiles=2)
prep = pem.analyze(sysm.tracer, pti_ns=20_000)
print(f"  Power-EM: avg {prep.avg_w:.2f} W, peak {prep.peak_w:.2f} W, "
      f"{prep.energy_j()*1e3:.3f} mJ/inference")

arrays = from_tasks(cw.tasks)
pm = np.stack([params_of(hw.replace(clock_ghz=f))
               for f in (0.4, 0.7, 1.0, 1.3)])
res = schedule_many(arrays, pm)
print(f"  vectorized 4-freq sweep (one XLA call): "
      f"{[f'{t/1e6:.2f}ms' for t in res]}")
print("\nquickstart OK")
